//! The NUMA machine simulator.
//!
//! Epoch-driven (fixed `dt`): each tick prices memory accesses with the
//! previous tick's controller utilization (lagged fixed point), advances
//! every thread by `cpu_share * speed`, accumulates new controller
//! demand, and lets the (NUMA-blind) OS load balancer shuffle threads —
//! producing exactly the pathologies the paper's user-level scheduler
//! repairs: threads drifting away from their pages, controllers
//! saturating while neighbours idle.
//!
//! The machine implements `ProcSource` by rendering its state into real
//! kernel text formats, so the Monitor observes it exactly as it would a
//! live host.

use std::collections::BTreeMap;

use crate::procfs::{numa_maps, stat, sysnode, ProcSource};
use crate::topology::NumaTopology;
use crate::util::rng::Rng;

use super::memctl::MemCtl;
use super::page::PageMap;
use super::process::SimProcess;
use super::task::TaskBehavior;

/// Memory-stall weight: how strongly (normalized) access cost slows a
/// fully memory-bound thread. Calibrated with `memctl::QUEUE_WEIGHT` so
/// saturated-remote hits the paper's >90 % degradation (Fig 6).
pub const MEM_WEIGHT: f64 = 2.5;

/// Peak controller demand of one fully memory-bound thread, GB/s.
pub const THREAD_PEAK_GBS: f64 = 1.6;

/// Page-migration throughput budget, pages per virtual ms.
pub const MIG_PAGES_PER_MS: u64 = 4000;

/// Controller traffic charged per migrated page (read + write), GB per page.
pub const MIG_GB_PER_PAGE: f64 = 2.0 * 4096.0 / 1e9;

/// Where to place a spawning process's threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// NUMA-blind: globally least-loaded cores (the OS default).
    LeastLoaded,
    /// All threads on one node's cores.
    Node(usize),
}

/// The simulated machine.
pub struct Machine {
    pub topo: NumaTopology,
    pub now_ms: f64,
    pub dt_ms: f64,
    procs: BTreeMap<i32, SimProcess>,
    ctls: Vec<MemCtl>,
    /// Run queue per core: (pid, thread index).
    cores: Vec<Vec<(i32, usize)>>,
    next_pid: i32,
    rng: Rng,
    /// NUMA-blind OS thread balancing (on under every policy; the paper's
    /// scheduler corrects it rather than replacing the OS).
    pub os_balance: bool,
    /// Cumulative per-node access counters (rendered as numastat).
    numastat: Vec<sysnode::NumaStat>,
    /// Migration traffic to charge to controllers next tick, GB/s-equiv.
    mig_charge: Vec<f64>,
    /// Total process migrations executed (metrics).
    pub total_migrations: u64,
    /// Total pages migrated (metrics).
    pub total_pages_migrated: u64,
}

impl Machine {
    pub fn new(topo: NumaTopology, seed: u64) -> Self {
        topo.validate().expect("invalid topology");
        let nodes = topo.nodes;
        let cores = topo.total_cores();
        Self {
            ctls: topo.bandwidth_gbs.iter().map(|&b| MemCtl::new(b)).collect(),
            cores: vec![Vec::new(); cores],
            topo,
            now_ms: 0.0,
            dt_ms: 1.0,
            procs: BTreeMap::new(),
            next_pid: 1000,
            rng: Rng::new(seed),
            os_balance: true,
            numastat: vec![sysnode::NumaStat::default(); nodes],
            mig_charge: vec![0.0; nodes],
            total_migrations: 0,
            total_pages_migrated: 0,
        }
    }

    // ---------------------------------------------------------------- spawn

    /// Launch a process; returns its pid. Pages are first-touch allocated
    /// according to the initial thread placement.
    pub fn spawn(
        &mut self,
        comm: &str,
        behavior: TaskBehavior,
        importance: f64,
        nthreads: usize,
        placement: Placement,
    ) -> i32 {
        behavior.validate().expect("invalid behavior");
        assert!(nthreads > 0, "process needs threads");
        let pid = self.next_pid;
        self.next_pid += 1;
        let mut p = SimProcess::new(pid, comm, behavior, importance, self.now_ms);
        for t in 0..nthreads {
            let core = match placement {
                Placement::LeastLoaded => self.least_loaded_core_global(),
                Placement::Node(n) => self.least_loaded_core_on(n),
            };
            self.cores[core].push((pid, t));
            p.threads_core.push(core);
        }
        let weights = p.threads_per_node(self.topo.nodes, self.topo.cores_per_node);
        p.pages = PageMap::first_touch(self.topo.nodes, p.behavior.ws_pages, &weights);
        if let Placement::Node(n) = placement {
            p.pinned_node = None; // pinning is a separate, explicit call
            let _ = n;
        }
        self.procs.insert(pid, p);
        pid
    }

    fn least_loaded_core_global(&mut self) -> usize {
        let min = self.cores.iter().map(Vec::len).min().unwrap();
        let candidates: Vec<usize> = (0..self.cores.len())
            .filter(|&c| self.cores[c].len() == min)
            .collect();
        *self.rng.choice(&candidates)
    }

    fn least_loaded_core_on(&mut self, node: usize) -> usize {
        let range = self.topo.cores_of_node(node);
        let min = range.clone().map(|c| self.cores[c].len()).min().unwrap();
        let candidates: Vec<usize> =
            range.filter(|&c| self.cores[c].len() == min).collect();
        *self.rng.choice(&candidates)
    }

    // ------------------------------------------------------------ accessors

    pub fn process(&self, pid: i32) -> Option<&SimProcess> {
        self.procs.get(&pid)
    }

    pub fn processes(&self) -> impl Iterator<Item = &SimProcess> {
        self.procs.values()
    }

    pub fn running_pids(&self) -> Vec<i32> {
        self.procs
            .values()
            .filter(|p| p.is_running())
            .map(|p| p.pid)
            .collect()
    }

    pub fn all_finished(&self) -> bool {
        self.procs.values().all(|p| !p.is_running())
    }

    /// Committed utilization per node (what pricing uses this tick).
    pub fn node_rho(&self) -> Vec<f64> {
        self.ctls.iter().map(MemCtl::rho_raw).collect()
    }

    pub fn core_load(&self, core: usize) -> usize {
        self.cores[core].len()
    }

    // ----------------------------------------------------------- scheduling

    /// Pin a process to a node (admin static pin). Moves it there too.
    pub fn pin_process(&mut self, pid: i32, node: usize) {
        self.move_process(pid, node);
        if let Some(p) = self.procs.get_mut(&pid) {
            p.pinned_node = Some(node);
        }
    }

    /// Move all of a process's threads to cores of `node`.
    pub fn move_process(&mut self, pid: i32, node: usize) {
        assert!(node < self.topo.nodes);
        let Some(p) = self.procs.get(&pid) else { return };
        if !p.is_running() {
            return;
        }
        let nthreads = p.nthreads();
        // Detach from current cores.
        for q in self.cores.iter_mut() {
            q.retain(|&(qpid, _)| qpid != pid);
        }
        // Reattach on target node, least-loaded first.
        let mut new_cores = Vec::with_capacity(nthreads);
        for t in 0..nthreads {
            let core = self.least_loaded_core_on(node);
            self.cores[core].push((pid, t));
            new_cores.push(core);
        }
        let now = self.now_ms;
        let p = self.procs.get_mut(&pid).unwrap();
        p.threads_core = new_cores;
        p.migrations += 1;
        p.last_migration_ms = now;
        self.total_migrations += 1;
    }

    /// Migrate up to `budget` of a process's pages toward `node`,
    /// charging the migration traffic to the controllers involved.
    pub fn migrate_pages(&mut self, pid: i32, node: usize, budget: u64) -> u64 {
        assert!(node < self.topo.nodes);
        let Some(p) = self.procs.get_mut(&pid) else { return 0 };
        let moved = p.pages.migrate_toward(node, budget);
        if moved > 0 {
            let gb = moved as f64 * MIG_GB_PER_PAGE;
            // Traffic hits the destination controller (writes) and is
            // spread over the tick.
            self.mig_charge[node] += gb / (self.dt_ms / 1000.0);
            self.total_pages_migrated += moved;
        }
        moved
    }

    /// Auto-NUMA-style: migrate pages from `src` node to `dst` node.
    pub fn migrate_pages_from(&mut self, pid: i32, src: usize, dst: usize, budget: u64) -> u64 {
        let Some(p) = self.procs.get_mut(&pid) else { return 0 };
        let moved = p.pages.migrate_from(src, dst, budget);
        if moved > 0 {
            let gb = moved as f64 * MIG_GB_PER_PAGE;
            self.mig_charge[dst] += gb / (self.dt_ms / 1000.0);
            self.total_pages_migrated += moved;
        }
        moved
    }

    // ----------------------------------------------------------------- tick

    /// Advance virtual time by one `dt` tick.
    pub fn step(&mut self) {
        let nodes = self.topo.nodes;
        let cpn = self.topo.cores_per_node;
        let dt = self.dt_ms;

        // Pass 1: per-thread speeds priced at the previous tick's rho.
        let lat_mult: Vec<f64> = self.ctls.iter().map(MemCtl::latency_multiplier).collect();
        let mut new_demand = vec![0.0f64; nodes];
        let mut hits = vec![0u64; nodes];
        let mut misses = vec![0u64; nodes];

        for p in self.procs.values_mut() {
            if !p.is_running() || p.nthreads() == 0 {
                continue;
            }
            let mi = p.behavior.intensity_at(self.now_ms);
            let fracs = p.pages.fractions();
            // Per-thread raw speed.
            let mut speeds = Vec::with_capacity(p.nthreads());
            let mut shares = Vec::with_capacity(p.nthreads());
            for &core in &p.threads_core {
                let my_node = core / cpn;
                // Mean normalized access cost over the page distribution:
                // distance term + queueing term of the holding controller.
                let mut penalty = 0.0;
                for n in 0..nodes {
                    if fracs[n] == 0.0 {
                        continue;
                    }
                    let dist_pen = self.topo.distance[my_node][n] / 10.0 - 1.0;
                    let queue_pen = lat_mult[n] - 1.0;
                    penalty += fracs[n] * (dist_pen + queue_pen);
                }
                let speed = 1.0 / (1.0 + MEM_WEIGHT * mi * penalty);
                // Timeshare: the core splits dt across its run queue.
                let share = 1.0 / self.cores[core].len().max(1) as f64;
                speeds.push(speed);
                shares.push(share);
            }
            // Granularity coupling: fine-grained apps advance at the pace
            // of their slowest thread (barrier every few instructions).
            let min_speed = speeds.iter().copied().fold(f64::INFINITY, f64::min);
            let g = p.behavior.granularity;
            let mut work = 0.0;
            let mut cpu = 0.0;
            for (s, sh) in speeds.iter().zip(&shares) {
                let coupled = g * s + (1.0 - g) * min_speed;
                work += coupled * sh * dt;
                cpu += sh * dt;
                p.speed_sum += coupled;
                p.speed_samples += 1;
            }
            p.work_done += work;
            p.window_work += work;
            p.cpu_ms += cpu;

            // Demand lands where the pages are; exchange traffic rides on
            // top (producer/consumer copies between threads). Offered
            // load scales with CPU share but NOT with achieved speed:
            // memory-bound threads keep their miss queues full while
            // stalled (MLP), so a contended controller stays saturated —
            // this is what produces the paper's >90 % degradation under
            // stacking (Fig 6) instead of a self-throttling equilibrium.
            let offered: f64 = shares.iter().sum();
            let demand = mi * THREAD_PEAK_GBS * offered * (1.0 + p.behavior.exchange);
            let tpn = p.threads_per_node(nodes, cpn);
            let total_threads = p.nthreads() as f64;
            for n in 0..nodes {
                new_demand[n] += demand * fracs[n];
                // numastat semantics (ours): accesses *served by* node n,
                // split into local (issued by threads on n) and remote.
                // The Monitor recovers controller demand per node from
                // Δ(hit+miss) and locality from the hit/miss ratio.
                let thread_frac = tpn[n] as f64 / total_threads;
                let served = demand * fracs[n] * 1000.0;
                let local = served * thread_frac;
                hits[n] += local as u64;
                misses[n] += (served - local) as u64;
            }

            // Completion.
            if p.work_done >= p.behavior.work_units {
                p.finished_ms = Some(self.now_ms + dt);
            }
        }

        // Free cores of processes that just finished.
        let finished: Vec<i32> = self
            .procs
            .values()
            .filter(|p| p.finished_ms.is_some())
            .map(|p| p.pid)
            .collect();
        for core in self.cores.iter_mut() {
            core.retain(|(pid, _)| !finished.contains(pid));
        }

        // Commit controller demand (+ migration traffic) for next tick.
        for n in 0..nodes {
            self.ctls[n].add_demand(new_demand[n] + self.mig_charge[n]);
            self.ctls[n].commit_tick();
            self.mig_charge[n] = 0.0;
            self.numastat[n].numa_hit += hits[n];
            self.numastat[n].numa_miss += misses[n];
            self.numastat[n].local_node += hits[n];
            self.numastat[n].other_node += misses[n];
        }

        // NUMA-blind OS load balancing: equalize core run-queue lengths,
        // ignoring memory entirely (this is what strands tasks away from
        // their pages).
        if self.os_balance {
            self.os_rebalance();
        }

        self.now_ms += dt;
    }

    /// One CFS-flavoured balancing pass (NUMA-blind by design).
    fn os_rebalance(&mut self) {
        loop {
            let (max_c, max_len) = (0..self.cores.len())
                .map(|c| (c, self.cores[c].len()))
                .max_by_key(|&(_, l)| l)
                .unwrap();
            let (min_c, min_len) = (0..self.cores.len())
                .map(|c| (c, self.cores[c].len()))
                .min_by_key(|&(_, l)| l)
                .unwrap();
            if max_len <= min_len + 1 {
                break;
            }
            // Move one unpinned thread from the busiest to the idlest core.
            let Some(idx) = self.cores[max_c].iter().position(|&(pid, _)| {
                self.procs
                    .get(&pid)
                    .map(|p| p.pinned_node.is_none())
                    .unwrap_or(false)
            }) else {
                break;
            };
            let (pid, t) = self.cores[max_c].remove(idx);
            self.cores[min_c].push((pid, t));
            if let Some(p) = self.procs.get_mut(&pid) {
                p.threads_core[t] = min_c;
            }
        }
    }

    /// Run until `deadline_ms` or all processes finish.
    pub fn run_until(&mut self, deadline_ms: f64) {
        while self.now_ms < deadline_ms && !self.all_finished() {
            self.step();
        }
    }

    /// Reset daemon throughput windows; returns work done per pid since
    /// the last reset.
    pub fn drain_window_work(&mut self) -> BTreeMap<i32, f64> {
        let mut out = BTreeMap::new();
        for p in self.procs.values_mut() {
            out.insert(p.pid, p.window_work);
            p.window_work = 0.0;
        }
        out
    }
}

// `BTreeMap<i32, _>` helper: the `process()` accessor above needs a plain
// lookup; written as a method to keep the field private.
impl Machine {
    pub fn process_mut(&mut self, pid: i32) -> Option<&mut SimProcess> {
        self.procs.get_mut(&pid)
    }
}

impl ProcSource for Machine {
    fn list_pids(&self) -> Vec<i32> {
        self.procs
            .values()
            .filter(|p| p.is_running())
            .map(|p| p.pid)
            .collect()
    }

    fn read_stat(&self, pid: i32) -> Option<String> {
        let p = self.procs.get(&pid)?;
        if !p.is_running() {
            return None;
        }
        let s = stat::PidStat {
            pid: p.pid,
            comm: p.comm.clone(),
            state: 'R',
            utime: p.cpu_ms as u64, // 1 jiffy == 1 virtual ms
            stime: 0,
            num_threads: p.nthreads() as i64,
            vsize: p.pages.total() * 4096,
            rss: p.pages.total() as i64,
            processor: *p.threads_core.first().unwrap_or(&0) as i32,
        };
        Some(stat::render(&s))
    }

    fn read_numa_maps(&self, pid: i32) -> Option<String> {
        let p = self.procs.get(&pid)?;
        if !p.is_running() {
            return None;
        }
        let per_node: std::collections::BTreeMap<usize, u64> = p
            .pages
            .per_node
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(n, &c)| (n, c))
            .collect();
        let vma = numa_maps::Vma {
            address: 0x7f00_0000_0000 + ((p.pid as u64) << 24),
            policy: "default".into(),
            pages_per_node: per_node,
            anon: Some(p.pages.total()),
            dirty: Some(p.pages.total() / 2),
            file: None,
        };
        Some(numa_maps::render(&[vma]))
    }

    fn read_nodes_online(&self) -> Option<String> {
        Some(sysnode::render_cpulist(
            &(0..self.topo.nodes).collect::<Vec<_>>(),
        ))
    }

    fn read_node_cpulist(&self, node: usize) -> Option<String> {
        if node >= self.topo.nodes {
            return None;
        }
        Some(self.topo.cpulist(node))
    }

    fn read_node_distance(&self, node: usize) -> Option<String> {
        if node >= self.topo.nodes {
            return None;
        }
        Some(
            self.topo.distance[node]
                .iter()
                .map(|d| format!("{}", *d as i64))
                .collect::<Vec<_>>()
                .join(" "),
        )
    }

    fn read_node_numastat(&self, node: usize) -> Option<String> {
        if node >= self.topo.nodes {
            return None;
        }
        Some(sysnode::render_numastat(&self.numastat[node]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn machine() -> Machine {
        Machine::new(NumaTopology::r910_40core(), 42)
    }

    fn small_machine() -> Machine {
        Machine::new(
            NumaTopology::from_config(&MachineConfig::preset("2node-8core").unwrap()),
            7,
        )
    }

    #[test]
    fn spawn_places_threads_and_pages() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::cpu_bound(1e9), 1.0, 4, Placement::Node(2));
        let p = m.process_mut(pid).unwrap();
        assert_eq!(p.nthreads(), 4);
        assert_eq!(p.home_node(4, 10), 2);
        // First touch: all pages on node 2.
        assert_eq!(p.pages.per_node[2], p.pages.total());
    }

    #[test]
    fn solo_cpu_bound_runs_at_full_speed() {
        let mut m = machine();
        let behavior = TaskBehavior {
            mem_intensity: 0.0,
            ..TaskBehavior::cpu_bound(100.0)
        };
        let pid = m.spawn("solo", behavior, 1.0, 1, Placement::Node(0));
        m.run_until(1_000.0);
        let p = m.process_mut(pid).unwrap();
        // 100 work units at speed 1.0 on a private core = 100 ms.
        assert_eq!(p.runtime_ms(), Some(100.0));
    }

    #[test]
    fn remote_pages_slow_a_memory_bound_task() {
        // Task on node 0 with all pages on node 1 vs all pages local.
        let run = |local: bool| -> f64 {
            let mut m = small_machine();
            m.os_balance = false;
            let pid = m.spawn("t", TaskBehavior::mem_bound(200.0), 1.0, 1, Placement::Node(0));
            if !local {
                let p = m.process_mut(pid).unwrap();
                let total = p.pages.total();
                p.pages.per_node = vec![0, total];
            }
            m.run_until(50_000.0);
            m.process_mut(pid).unwrap().runtime_ms().unwrap()
        };
        let t_local = run(true);
        let t_remote = run(false);
        assert!(
            t_remote > t_local * 1.5,
            "remote {t_remote} vs local {t_local}"
        );
    }

    #[test]
    fn contention_degrades_throughput_severely_when_stacked() {
        // Fig 6 upper: many memory-bound co-runners hammering one node
        // degrade per-task speed severely vs solo (>90% on the paper's
        // box once remote access compounds; locally-pinned pure
        // contention must exceed 75% here).
        let mut solo = small_machine();
        solo.os_balance = false;
        let pid = solo.spawn("m", TaskBehavior::mem_bound(1e12), 1.0, 1, Placement::Node(0));
        solo.run_until(2_000.0);
        let solo_speed = solo.process_mut(pid).unwrap().mean_speed();

        let mut packed = small_machine();
        packed.os_balance = false;
        let victim = packed.spawn("m", TaskBehavior::mem_bound(1e12), 1.0, 1, Placement::Node(0));
        for _ in 0..7 {
            packed.spawn("hog", TaskBehavior::mem_bound(1e12), 1.0, 1, Placement::Node(0));
        }
        packed.run_until(2_000.0);
        let packed_speed = packed.process_mut(victim).unwrap().mean_speed();

        let degradation = 1.0 - packed_speed / solo_speed;
        assert!(
            degradation > 0.75,
            "stacked degradation too small: {degradation} (solo {solo_speed} packed {packed_speed})"
        );
    }

    #[test]
    fn move_process_relocates_all_threads() {
        let mut m = machine();
        m.os_balance = false;
        let pid = m.spawn("w", TaskBehavior::cpu_bound(1e9), 1.0, 6, Placement::Node(0));
        m.move_process(pid, 3);
        let p = m.process_mut(pid).unwrap();
        assert_eq!(p.threads_per_node(4, 10), vec![0, 0, 0, 6]);
        assert_eq!(p.migrations, 1);
    }

    #[test]
    fn migrate_pages_moves_and_charges_traffic() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        let moved = m.migrate_pages(pid, 1, 10_000);
        assert_eq!(moved, 10_000);
        assert!(m.mig_charge[1] > 0.0);
        m.step();
        // Charged traffic shows up in node 1's committed utilization.
        assert!(m.node_rho()[1] > 0.0);
    }

    #[test]
    fn os_balancer_spreads_threads_numa_blind() {
        let mut m = small_machine();
        // 8 threads spawned on node 0's 4 cores -> 2 per core.
        let pid = m.spawn("w", TaskBehavior::cpu_bound(1e9), 1.0, 8, Placement::Node(0));
        m.step();
        // Balancer should have pulled threads onto node 1's idle cores.
        let p = m.process_mut(pid).unwrap();
        let tpn = p.threads_per_node(2, 4);
        assert!(tpn[1] > 0, "balancer did not spread: {tpn:?}");
    }

    #[test]
    fn pinned_processes_resist_balancing() {
        let mut m = small_machine();
        let pid = m.spawn("w", TaskBehavior::cpu_bound(1e9), 1.0, 8, Placement::Node(0));
        m.pin_process(pid, 0);
        for _ in 0..10 {
            m.step();
        }
        let p = m.process_mut(pid).unwrap();
        assert_eq!(p.threads_per_node(2, 4), vec![8, 0]);
    }

    #[test]
    fn timesharing_halves_throughput() {
        let behavior = TaskBehavior {
            mem_intensity: 0.0,
            ..TaskBehavior::cpu_bound(100.0)
        };
        // Solo: 4 threads on 4 private cores -> 4 work/ms -> 25 ms.
        let mut solo = small_machine();
        solo.os_balance = false;
        let a = solo.spawn("a", behavior.clone(), 1.0, 4, Placement::Node(0));
        solo.run_until(10_000.0);
        let t_solo = solo.process_mut(a).unwrap().runtime_ms().unwrap();
        assert!((t_solo - 25.0).abs() < 2.0, "t_solo={t_solo}");

        // Shared: two such processes on the same 4 cores -> 50% shares,
        // both finish in ~2x the solo time.
        let mut m = small_machine();
        m.os_balance = false;
        let a = m.spawn("a", behavior.clone(), 1.0, 4, Placement::Node(0));
        let b = m.spawn("b", behavior.clone(), 1.0, 4, Placement::Node(0));
        m.run_until(10_000.0);
        let ta = m.process_mut(a).unwrap().runtime_ms().unwrap();
        let tb = m.process_mut(b).unwrap().runtime_ms().unwrap();
        assert!((ta - 2.0 * t_solo).abs() < 5.0, "ta={ta}");
        assert!((tb - 2.0 * t_solo).abs() < 5.0, "tb={tb}");
    }

    #[test]
    fn procsource_stat_roundtrips() {
        let mut m = machine();
        let pid = m.spawn("canneal", TaskBehavior::mem_bound(1e9), 1.0, 3, Placement::Node(1));
        m.step();
        let text = m.read_stat(pid).unwrap();
        let parsed = stat::parse(&text).unwrap();
        assert_eq!(parsed.pid, pid);
        assert_eq!(parsed.comm, "canneal");
        assert_eq!(parsed.num_threads, 3);
        assert!(parsed.rss > 0);
        let node = parsed.processor as usize / 10;
        assert_eq!(node, 1);
    }

    #[test]
    fn procsource_numa_maps_roundtrips() {
        let mut m = machine();
        let pid = m.spawn("dedup", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(2));
        let text = m.read_numa_maps(pid).unwrap();
        let maps = numa_maps::parse(&text);
        let per_node = maps.pages_per_node(4);
        assert_eq!(per_node[2], m.process_mut(pid).unwrap().pages.total());
    }

    #[test]
    fn procsource_sysfs_views() {
        let m = machine();
        assert_eq!(m.read_nodes_online().unwrap(), "0-3");
        assert_eq!(m.read_node_cpulist(1).unwrap(), "10-19");
        let d = sysnode::parse_distance_row(&m.read_node_distance(0).unwrap()).unwrap();
        assert_eq!(d.len(), 4);
        assert_eq!(d[0], 10.0);
        assert!(m.read_node_cpulist(9).is_none());
    }

    #[test]
    fn numastat_accumulates_hits_and_misses() {
        let mut m = small_machine();
        m.os_balance = false;
        let pid = m.spawn("t", TaskBehavior::mem_bound(1e12), 1.0, 1, Placement::Node(0));
        // Split pages across both nodes -> both hits and misses.
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node = vec![total / 2, total - total / 2];
        }
        for _ in 0..20 {
            m.step();
        }
        // Node 0 serves local accesses (threads there), node 1 serves
        // remote ones (pages there, threads elsewhere).
        let s0 = sysnode::parse_numastat(&m.read_node_numastat(0).unwrap());
        let s1 = sysnode::parse_numastat(&m.read_node_numastat(1).unwrap());
        assert!(s0.numa_hit > 0);
        assert!(s1.numa_miss > 0);
        assert_eq!(s1.numa_hit, 0);
    }

    #[test]
    fn finished_pids_disappear_from_procfs() {
        let mut m = machine();
        let behavior = TaskBehavior {
            mem_intensity: 0.0,
            ..TaskBehavior::cpu_bound(5.0)
        };
        let pid = m.spawn("quick", behavior, 1.0, 1, Placement::Node(0));
        m.run_until(1_000.0);
        assert!(m.read_stat(pid).is_none());
        assert!(!m.list_pids().contains(&pid));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || -> f64 {
            let mut m = machine();
            let pid = m.spawn("w", TaskBehavior::mem_bound(500.0), 1.0, 4, Placement::LeastLoaded);
            for _ in 0..4 {
                m.spawn("bg", TaskBehavior::mem_bound(1e9), 1.0, 4, Placement::LeastLoaded);
            }
            m.run_until(20_000.0);
            m.process_mut(pid).unwrap().runtime_ms().unwrap()
        };
        assert_eq!(run(), run());
    }
}
