//! The NUMA multicore system simulator — the substrate standing in for
//! the paper's DELL R910 testbed (see DESIGN.md §2 for the substitution
//! argument).
//!
//! Components:
//! * [`task`] — workload behaviour models (intensity, sharing, phases);
//! * [`page`] — per-process page placement and migration;
//! * [`memctl`] — per-node memory-controller queueing contention;
//! * [`process`] — thread placement and progress accounting;
//! * [`machine`] — the tick loop, the NUMA-blind OS balancer, and the
//!   `ProcSource` rendering that feeds the Monitor real kernel text.

pub mod machine;
pub mod memctl;
pub mod page;
pub mod process;
pub mod task;

pub use machine::{Machine, Placement};
pub use task::TaskBehavior;
