//! Simulated processes: thread placement, page map, progress accounting.

use super::page::PageMap;
use super::task::TaskBehavior;

/// One simulated process (the scheduling unit of Algorithm 3 — the paper
//  migrates whole processes plus their sticky pages).
#[derive(Clone, Debug)]
pub struct SimProcess {
    pub pid: i32,
    pub comm: String,
    /// User-space importance weight — what kernel-level schedulers cannot
    /// see and the paper's user-level scheduler exploits.
    pub importance: f64,
    pub behavior: TaskBehavior,
    /// Global core id of each thread.
    pub threads_core: Vec<usize>,
    pub pages: PageMap,
    /// Static admin pin (StaticTuning baseline / Algorithm 3 input).
    pub pinned_node: Option<usize>,
    /// Abstract work completed.
    pub work_done: f64,
    /// Work completed in the current measurement window (daemons).
    pub window_work: f64,
    /// Total CPU time consumed, virtual ms.
    pub cpu_ms: f64,
    pub started_ms: f64,
    pub finished_ms: Option<f64>,
    /// Process migrations performed on it.
    pub migrations: u64,
    /// Virtual time of the last migration (cooldown bookkeeping).
    pub last_migration_ms: f64,
    /// Running average of instantaneous speed (for metrics).
    pub speed_sum: f64,
    pub speed_samples: u64,
    /// Reused tick buffers + the epoch-keyed fraction cache (see
    /// [`TickScratch`]). Pure derived state: never read outside one
    /// `Machine::step` call except through its own validity key.
    pub scratch: TickScratch,
}

/// Per-process hot-loop scratch, persisted across ticks so `step()`
/// does zero per-process allocations at fleet scale. `fracs` doubles
/// as a cache: it is keyed on the page map's `(generation,
/// fingerprint)` epoch, so a process whose pages did not move skips
/// the per-node division pass entirely — the same epoch contract the
/// numa_maps render cache and the monitor's incremental snapshots
/// validate against. Cached values are bit-identical to recomputation
/// (they *are* the previous computation's output, and any content
/// change moves the epoch).
#[derive(Clone, Debug, Default)]
pub struct TickScratch {
    /// Cached `pages.fractions()` output.
    pub fracs: Vec<f64>,
    /// Epoch the cached fractions were computed at.
    pub fracs_epoch: Option<(u64, u64)>,
    /// Threads-per-node buffer (placement changes every balancer pass,
    /// so this one is recomputed each tick — but into a reused buffer).
    pub tpn: Vec<u64>,
    /// Per-thread speed/share buffers for the coupling pass.
    pub speeds: Vec<f64>,
    pub shares: Vec<f64>,
}

impl SimProcess {
    pub fn new(
        pid: i32,
        comm: &str,
        behavior: TaskBehavior,
        importance: f64,
        started_ms: f64,
    ) -> Self {
        Self {
            pid,
            comm: comm.to_string(),
            importance,
            behavior,
            threads_core: Vec::new(),
            pages: PageMap::empty(0),
            pinned_node: None,
            work_done: 0.0,
            window_work: 0.0,
            cpu_ms: 0.0,
            started_ms,
            finished_ms: None,
            migrations: 0,
            last_migration_ms: f64::NEG_INFINITY,
            speed_sum: 0.0,
            speed_samples: 0,
            scratch: TickScratch::default(),
        }
    }

    pub fn is_running(&self) -> bool {
        self.finished_ms.is_none()
    }

    pub fn nthreads(&self) -> usize {
        self.threads_core.len()
    }

    /// Threads per node, given the core->node mapping width.
    pub fn threads_per_node(&self, nodes: usize, cores_per_node: usize) -> Vec<u64> {
        let mut out = Vec::new();
        self.threads_per_node_into(nodes, cores_per_node, &mut out);
        out
    }

    /// [`Self::threads_per_node`] into a reused buffer (the tick hot
    /// loop's zero-allocation variant). Identical values.
    pub fn threads_per_node_into(
        &self,
        nodes: usize,
        cores_per_node: usize,
        out: &mut Vec<u64>,
    ) {
        out.clear();
        out.resize(nodes, 0);
        for &c in &self.threads_core {
            out[c / cores_per_node] += 1;
        }
    }

    /// Node hosting the majority of threads (ties -> lowest id).
    pub fn home_node(&self, nodes: usize, cores_per_node: usize) -> usize {
        let counts = self.threads_per_node(nodes, cores_per_node);
        counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(n, _)| n)
            .unwrap_or(0)
    }

    /// Completion time if finished.
    pub fn runtime_ms(&self) -> Option<f64> {
        self.finished_ms.map(|f| f - self.started_ms)
    }

    /// Mean observed speed (1.0 = unimpeded).
    pub fn mean_speed(&self) -> f64 {
        if self.speed_samples == 0 {
            0.0
        } else {
            self.speed_sum / self.speed_samples as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn proc_with_cores(cores: Vec<usize>) -> SimProcess {
        let mut p = SimProcess::new(1, "t", TaskBehavior::cpu_bound(10.0), 1.0, 0.0);
        p.threads_core = cores;
        p
    }

    #[test]
    fn threads_per_node_counts() {
        let p = proc_with_cores(vec![0, 1, 10, 11, 12]);
        assert_eq!(p.threads_per_node(4, 10), vec![2, 3, 0, 0]);
    }

    #[test]
    fn home_node_is_majority() {
        let p = proc_with_cores(vec![0, 10, 11]);
        assert_eq!(p.home_node(4, 10), 1);
    }

    #[test]
    fn home_node_tie_prefers_lowest() {
        let p = proc_with_cores(vec![0, 10]);
        assert_eq!(p.home_node(4, 10), 0);
    }

    #[test]
    fn runtime_only_when_finished() {
        let mut p = proc_with_cores(vec![0]);
        assert_eq!(p.runtime_ms(), None);
        p.started_ms = 100.0;
        p.finished_ms = Some(350.0);
        assert_eq!(p.runtime_ms(), Some(250.0));
    }

    #[test]
    fn mean_speed_empty_is_zero() {
        let p = proc_with_cores(vec![]);
        assert_eq!(p.mean_speed(), 0.0);
    }
}
