//! Per-process page placement: where a process's working set lives.
//!
//! The real system tracks this in the page tables and surfaces it via
//! `/proc/<pid>/numa_maps`; the simulator keeps per-node page counts and
//! a migration ledger (migrations consume controller bandwidth, which is
//! exactly why Algorithm 3 only moves "sticky" pages when degradation is
//! already high).

/// Page placement of one process across NUMA nodes.
#[derive(Clone, Debug)]
pub struct PageMap {
    /// Resident pages per node.
    pub per_node: Vec<u64>,
    /// Cumulative pages migrated (for metrics / cost accounting).
    pub migrated_total: u64,
}

impl PageMap {
    pub fn empty(nodes: usize) -> Self {
        Self { per_node: vec![0; nodes], migrated_total: 0 }
    }

    /// First-touch allocation: distribute `pages` proportionally to the
    /// thread placement `weights` (threads-per-node), like Linux does when
    /// faulting in pages from the allocating CPU.
    pub fn first_touch(nodes: usize, pages: u64, weights: &[u64]) -> Self {
        assert_eq!(weights.len(), nodes);
        let mut map = Self::empty(nodes);
        let total_w: u64 = weights.iter().sum();
        if total_w == 0 {
            // No threads placed yet — everything lands on node 0.
            map.per_node[0] = pages;
            return map;
        }
        let mut allocated = 0u64;
        for n in 0..nodes {
            let share = pages * weights[n] / total_w;
            map.per_node[n] = share;
            allocated += share;
        }
        // Rounding remainder goes to the heaviest node.
        let heaviest = (0..nodes).max_by_key(|&n| weights[n]).unwrap();
        map.per_node[heaviest] += pages - allocated;
        map
    }

    pub fn total(&self) -> u64 {
        self.per_node.iter().sum()
    }

    /// Fraction of pages on each node (all zeros if empty).
    pub fn fractions(&self) -> Vec<f64> {
        let total = self.total();
        if total == 0 {
            return vec![0.0; self.per_node.len()];
        }
        self.per_node
            .iter()
            .map(|&p| p as f64 / total as f64)
            .collect()
    }

    /// Move up to `budget` pages toward `target`, taking from the node
    /// with the most pages first (hottest remote chunk). Returns pages
    /// actually moved — the caller charges that traffic to the
    /// controllers involved.
    pub fn migrate_toward(&mut self, target: usize, budget: u64) -> u64 {
        assert!(target < self.per_node.len());
        let mut moved = 0;
        let mut remaining = budget;
        while remaining > 0 {
            let Some(src) = self
                .per_node
                .iter()
                .enumerate()
                .filter(|&(n, &p)| n != target && p > 0)
                .max_by_key(|&(_, &p)| p)
                .map(|(n, _)| n)
            else {
                break;
            };
            let chunk = self.per_node[src].min(remaining);
            self.per_node[src] -= chunk;
            self.per_node[target] += chunk;
            moved += chunk;
            remaining -= chunk;
        }
        self.migrated_total += moved;
        moved
    }

    /// Move up to `budget` pages from `src` to `dst` (auto-NUMA style
    /// single-origin migration). Returns pages moved.
    pub fn migrate_from(&mut self, src: usize, dst: usize, budget: u64) -> u64 {
        if src == dst {
            return 0;
        }
        let chunk = self.per_node[src].min(budget);
        self.per_node[src] -= chunk;
        self.per_node[dst] += chunk;
        self.migrated_total += chunk;
        chunk
    }

    /// Locality of a thread distribution: Σ_n thread_frac[n]*page_frac[n].
    pub fn locality(&self, thread_frac: &[f64]) -> f64 {
        self.fractions()
            .iter()
            .zip(thread_frac)
            .map(|(p, t)| p * t)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_follows_threads() {
        let m = PageMap::first_touch(4, 1000, &[3, 1, 0, 0]);
        assert_eq!(m.total(), 1000);
        assert_eq!(m.per_node[0], 750);
        assert_eq!(m.per_node[1], 250);
        assert_eq!(m.per_node[2], 0);
    }

    #[test]
    fn first_touch_remainder_conserved() {
        let m = PageMap::first_touch(3, 100, &[1, 1, 1]);
        assert_eq!(m.total(), 100);
    }

    #[test]
    fn first_touch_no_threads_lands_on_node0() {
        let m = PageMap::first_touch(2, 10, &[0, 0]);
        assert_eq!(m.per_node, vec![10, 0]);
    }

    #[test]
    fn fractions_sum_to_one() {
        let m = PageMap::first_touch(4, 999, &[1, 2, 3, 4]);
        let sum: f64 = m.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn migrate_toward_respects_budget_and_conserves() {
        let mut m = PageMap::first_touch(4, 1000, &[1, 1, 1, 1]);
        let before = m.total();
        let moved = m.migrate_toward(0, 300);
        assert_eq!(moved, 300);
        assert_eq!(m.total(), before);
        assert_eq!(m.per_node[0], 550);
        assert_eq!(m.migrated_total, 300);
    }

    #[test]
    fn migrate_toward_stops_when_fully_local() {
        let mut m = PageMap::empty(2);
        m.per_node[0] = 100;
        let moved = m.migrate_toward(0, 1000);
        assert_eq!(moved, 0);
        assert_eq!(m.per_node[0], 100);
    }

    #[test]
    fn migrate_from_single_origin() {
        let mut m = PageMap::empty(3);
        m.per_node = vec![50, 30, 20];
        assert_eq!(m.migrate_from(1, 2, 100), 30);
        assert_eq!(m.per_node, vec![50, 0, 50]);
        assert_eq!(m.migrate_from(0, 0, 10), 0);
    }

    #[test]
    fn locality_extremes() {
        let mut m = PageMap::empty(2);
        m.per_node = vec![100, 0];
        assert!((m.locality(&[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((m.locality(&[0.0, 1.0]) - 0.0).abs() < 1e-12);
    }
}
