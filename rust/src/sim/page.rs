//! Per-process page placement: where a process's working set lives.
//!
//! The real system tracks this in the page tables and surfaces it via
//! `/proc/<pid>/numa_maps`; the simulator keeps per-node page counts and
//! a migration ledger (migrations consume controller bandwidth, which is
//! exactly why Algorithm 3 only moves "sticky" pages when degradation is
//! already high).
//!
//! Since the `mem` subsystem landed, placement is **tiered**: a working
//! set is some mix of 4 KiB base pages, 2 MiB huge pages, and 1 GiB
//! giant pages per node. Counts are kept in each tier's own units;
//! totals, fractions, and migration budgets are in 4 KiB *equivalents*
//! so every consumer of the old flat model keeps its semantics. The
//! ledger distinguishes bandwidth (scales with bytes — one 2 MiB move
//! costs 512 base moves) from operations (one per page of any tier —
//! where huge pages win).
//!
//! Layout is **struct-of-arrays**: all three tiers live in one flat
//! tier-major `Vec<u64>` (`counts[tier * nodes + n]`), so the sweep
//! inner loop walks one contiguous allocation per process instead of
//! chasing three Vec pointers. Callers read tiers through the slice
//! accessors ([`PageMap::per_node`] etc.); the `_mut` variants
//! deliberately do **not** bump the generation counter — direct writes
//! (scenario setup, tests) are caught by [`PageMap::fingerprint`], the
//! same contract the old public fields had.

use crate::mem::PageTier;

/// Tier rows of the flat count matrix, in fingerprint order.
const TIER_BASE: usize = 0;
const TIER_HUGE: usize = 1;
const TIER_GIANT: usize = 2;
const TIERS: usize = 3;

fn tier_row(tier: PageTier) -> usize {
    match tier {
        PageTier::Base4K => TIER_BASE,
        PageTier::Huge2M => TIER_HUGE,
        PageTier::Giant1G => TIER_GIANT,
    }
}

/// Page placement of one process across NUMA nodes, per tier.
#[derive(Clone, Debug)]
pub struct PageMap {
    /// Tier-major count matrix: `counts[tier * nodes + n]` — row 0 is
    /// resident 4 KiB base pages, row 1 is 2 MiB huge pages (2 MiB
    /// units), row 2 is 1 GiB giant pages (1 GiB units).
    counts: Vec<u64>,
    nodes: usize,
    /// Cumulative 4 KiB-equivalent pages migrated (bandwidth ledger).
    pub migrated_total: u64,
    /// Cumulative migration operations — one per page of any tier (the
    /// `migrate_pages(2)` call-volume ledger huge pages shrink).
    pub migrate_ops: u64,
    /// Placement-change counter: bumped by every mutating method, so
    /// `ProcSource` facades can cache rendered numa_maps text and skip
    /// re-rendering processes whose pages did not move.
    generation: u64,
}

impl PageMap {
    pub fn empty(nodes: usize) -> Self {
        Self {
            counts: vec![0; TIERS * nodes],
            nodes,
            migrated_total: 0,
            migrate_ops: 0,
            generation: 0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Resident 4 KiB base pages per node.
    pub fn per_node(&self) -> &[u64] {
        &self.counts[..self.nodes]
    }

    /// Resident 2 MiB huge pages per node (2 MiB units).
    pub fn huge_2m(&self) -> &[u64] {
        &self.counts[self.nodes..2 * self.nodes]
    }

    /// Resident 1 GiB giant pages per node (1 GiB units).
    pub fn giant_1g(&self) -> &[u64] {
        &self.counts[2 * self.nodes..3 * self.nodes]
    }

    /// One tier's counts, by tier.
    pub fn tier(&self, tier: PageTier) -> &[u64] {
        let row = tier_row(tier) * self.nodes;
        &self.counts[row..row + self.nodes]
    }

    /// Direct write access to the base-tier counts. Does **not** bump
    /// the generation — the fingerprint catches such writes, exactly as
    /// it caught writes to the old public field.
    pub fn per_node_mut(&mut self) -> &mut [u64] {
        &mut self.counts[..self.nodes]
    }

    /// Direct write access to the 2 MiB-tier counts (no generation bump).
    pub fn huge_2m_mut(&mut self) -> &mut [u64] {
        let n = self.nodes;
        &mut self.counts[n..2 * n]
    }

    /// Direct write access to the 1 GiB-tier counts (no generation bump).
    pub fn giant_1g_mut(&mut self) -> &mut [u64] {
        let n = self.nodes;
        &mut self.counts[2 * n..3 * n]
    }

    /// Current placement generation (see [`Self::bump_generation`]).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Record that placement changed — invalidates cached renders of
    /// this map. Called by every mutating method; callers that write
    /// through the `_mut` slice accessors directly (scenario setup,
    /// tests) are caught by [`Self::fingerprint`] instead.
    pub fn bump_generation(&mut self) {
        self.generation = self.generation.wrapping_add(1);
    }

    /// Order-sensitive FNV-1a-style fingerprint over every tier count.
    /// Belt-and-braces companion to the generation counter: it catches
    /// direct writes through the `_mut` accessors (which bypass
    /// `bump_generation`), including permutations that preserve totals.
    /// O(nodes) — far cheaper than re-rendering. The flat tier-major
    /// layout iterates in exactly the old per-tier-Vec order, so hash
    /// values are unchanged across the SoA refactor.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        for tier in 0..TIERS {
            for &c in &self.counts[tier * self.nodes..(tier + 1) * self.nodes] {
                h ^= c.wrapping_add(0x9e37_79b9_7f4a_7c15);
                h = h.wrapping_mul(PRIME);
            }
            // Tier separator so e.g. moving a count between tiers with
            // equal values still changes the hash.
            h ^= 0xff;
            h = h.wrapping_mul(PRIME);
        }
        h
    }

    /// The `(generation, fingerprint)` pair — the cache key every
    /// placement-derived view (numa_maps render cache, the monitor's
    /// incremental snapshots, the tick's fraction cache) validates
    /// against.
    pub fn epoch(&self) -> (u64, u64) {
        (self.generation, self.fingerprint())
    }

    /// First-touch allocation: distribute `pages` (4 KiB units)
    /// proportionally to the thread placement `weights`
    /// (threads-per-node), like Linux does when faulting in pages from
    /// the allocating CPU. Everything lands in the base tier;
    /// [`Self::promote_to_huge`] upgrades afterwards (THP collapse).
    pub fn first_touch(nodes: usize, pages: u64, weights: &[u64]) -> Self {
        assert_eq!(weights.len(), nodes);
        let mut map = Self::empty(nodes);
        let total_w: u64 = weights.iter().sum();
        if total_w == 0 {
            // No threads placed yet — everything lands on node 0.
            map.counts[0] = pages;
            return map;
        }
        let mut allocated = 0u64;
        for n in 0..nodes {
            let share = pages * weights[n] / total_w;
            map.counts[n] = share;
            allocated += share;
        }
        // Rounding remainder goes to the heaviest node; weight ties
        // break toward the lowest node id (matching round_robin_pins'
        // least-occupied-first convention), not `max_by_key`'s
        // last-maximum bias toward the highest-numbered node.
        let heaviest = (0..nodes)
            .max_by_key(|&n| (weights[n], std::cmp::Reverse(n)))
            .unwrap();
        map.counts[heaviest] += pages - allocated;
        map
    }

    /// Tier collapse: on each node, convert base pages into pages of
    /// `tier` — up to `want_frac` of the node's base pages and bounded
    /// by `pool_free[n]` (the node's free pool of that tier). Returns
    /// pages taken per node so the machine can debit its pools.
    pub fn promote_to_tier(
        &mut self,
        tier: PageTier,
        want_frac: f64,
        pool_free: &[u64],
    ) -> Vec<u64> {
        assert!(
            !matches!(tier, PageTier::Base4K),
            "base pages need no promotion"
        );
        assert_eq!(pool_free.len(), self.nodes);
        let per = tier.pages_4k();
        let row = tier_row(tier) * self.nodes;
        let mut taken = vec![0u64; self.nodes];
        if want_frac <= 0.0 {
            return taken;
        }
        for n in 0..self.nodes {
            let want = ((self.counts[n] as f64 * want_frac.min(1.0)) as u64) / per;
            let got = want.min(pool_free[n]);
            if got == 0 {
                continue;
            }
            self.counts[n] -= got * per;
            self.counts[row + n] += got;
            taken[n] = got;
            self.bump_generation();
        }
        taken
    }

    /// THP collapse into 2 MiB pages (the common case).
    pub fn promote_to_huge(&mut self, want_frac: f64, pool_free: &[u64]) -> Vec<u64> {
        self.promote_to_tier(PageTier::Huge2M, want_frac, pool_free)
    }

    /// 4 KiB-equivalent pages on one node, across tiers.
    pub fn node_total(&self, n: usize) -> u64 {
        self.counts[n]
            + self.counts[self.nodes + n] * PageTier::Huge2M.pages_4k()
            + self.counts[2 * self.nodes + n] * PageTier::Giant1G.pages_4k()
    }

    /// Total resident 4 KiB-equivalent pages.
    pub fn total(&self) -> u64 {
        (0..self.nodes).map(|n| self.node_total(n)).sum()
    }

    /// Live page-table mappings (pages of any tier each count once) —
    /// what the TLB must cover.
    pub fn mappings(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of (4 KiB-equivalent) pages on each node.
    pub fn fractions(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.fractions_into(&mut out);
        out
    }

    /// [`Self::fractions`] into a reused buffer — the tick hot loop's
    /// zero-allocation variant. Identical values in identical order.
    pub fn fractions_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.nodes, 0.0);
        let total = self.total();
        if total == 0 {
            return;
        }
        for (n, slot) in out.iter_mut().enumerate() {
            *slot = self.node_total(n) as f64 / total as f64;
        }
    }

    /// Move up to `budget` 4 KiB-equivalent pages from `src` to `dst`,
    /// largest tier first: a whole huge page is one ledger op for 512
    /// equivalents, so under the same budget the mover prefers few big
    /// pages over many small ones (tier-aware sticky migration).
    /// Returns equivalents moved.
    fn move_tiered(&mut self, src: usize, dst: usize, budget: u64) -> u64 {
        let mut moved = 0u64;
        let mut remaining = budget;
        for tier in [PageTier::Giant1G, PageTier::Huge2M, PageTier::Base4K] {
            let per_page = tier.pages_4k();
            let row = tier_row(tier) * self.nodes;
            let avail = self.counts[row + src];
            // Whole pages only: a 1 GiB page does not move piecewise.
            let chunk = avail.min(remaining / per_page);
            if chunk == 0 {
                continue;
            }
            self.counts[row + src] -= chunk;
            self.counts[row + dst] += chunk;
            moved += chunk * per_page;
            remaining -= chunk * per_page;
            self.migrate_ops += chunk;
        }
        if moved > 0 {
            self.bump_generation();
        }
        moved
    }

    /// Move up to `budget` (4 KiB-equivalent) pages toward `target`,
    /// taking from the node with the most pages first (hottest remote
    /// chunk). Returns equivalents actually moved — the caller charges
    /// that traffic to the controllers involved.
    pub fn migrate_toward(&mut self, target: usize, budget: u64) -> u64 {
        assert!(target < self.nodes);
        let mut moved = 0;
        let mut remaining = budget;
        while remaining > 0 {
            // Hottest remote chunk first; fall through to cooler nodes
            // when the hottest holds only whole pages bigger than the
            // remaining budget.
            let mut srcs: Vec<usize> = (0..self.nodes)
                .filter(|&n| n != target && self.node_total(n) > 0)
                .collect();
            // Ties break toward the highest node id, matching the old
            // flat mover's `max_by_key` (which kept the last maximum) —
            // seed experiment trajectories stay bit-identical.
            srcs.sort_by_key(|&n| (std::cmp::Reverse(self.node_total(n)), std::cmp::Reverse(n)));
            let mut progressed = false;
            for src in srcs {
                let chunk = self.move_tiered(src, target, remaining);
                if chunk > 0 {
                    moved += chunk;
                    remaining -= chunk;
                    progressed = true;
                    break;
                }
            }
            if !progressed {
                break;
            }
        }
        self.migrated_total += moved;
        moved
    }

    /// Move up to `budget` equivalents from `src` to `dst` (auto-NUMA
    /// style single-origin migration). Returns equivalents moved.
    pub fn migrate_from(&mut self, src: usize, dst: usize, budget: u64) -> u64 {
        if src == dst {
            return 0;
        }
        let moved = self.move_tiered(src, dst, budget);
        self.migrated_total += moved;
        moved
    }

    /// Locality of a thread distribution: Σ_n thread_frac[n]*page_frac[n].
    pub fn locality(&self, thread_frac: &[f64]) -> f64 {
        self.fractions()
            .iter()
            .zip(thread_frac)
            .map(|(p, t)| p * t)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_follows_threads() {
        let m = PageMap::first_touch(4, 1000, &[3, 1, 0, 0]);
        assert_eq!(m.total(), 1000);
        assert_eq!(m.per_node()[0], 750);
        assert_eq!(m.per_node()[1], 250);
        assert_eq!(m.per_node()[2], 0);
    }

    #[test]
    fn first_touch_remainder_conserved() {
        let m = PageMap::first_touch(3, 100, &[1, 1, 1]);
        assert_eq!(m.total(), 100);
    }

    #[test]
    fn first_touch_remainder_lands_on_heaviest() {
        // 100 pages over weights [2, 3, 3]: floor shares are 25/37/37,
        // remainder 1 goes to the heaviest node (ties -> lowest id).
        let m = PageMap::first_touch(3, 100, &[2, 3, 3]);
        assert_eq!(m.total(), 100);
        assert_eq!(m.per_node(), &[25, 38, 37]);
    }

    #[test]
    fn first_touch_remainder_tie_breaks_to_lowest_node() {
        // All-equal weights: 10 pages over [1, 1, 1] floor to 3/3/3 with
        // remainder 1 — the spill must land on node 0, not max_by_key's
        // last maximum (node 2). Regression test for the highest-node
        // spill bias.
        let m = PageMap::first_touch(3, 10, &[1, 1, 1]);
        assert_eq!(m.per_node(), &[4, 3, 3]);
        // A later heavier node still wins outright (no tie involved)...
        let m = PageMap::first_touch(3, 10, &[1, 1, 2]);
        assert_eq!(m.per_node(), &[2, 2, 6]);
        // ...and a leading tie among heaviest nodes picks the lowest.
        let m = PageMap::first_touch(4, 103, &[0, 5, 5, 0]);
        assert_eq!(m.per_node(), &[0, 52, 51, 0]);
    }

    #[test]
    fn first_touch_no_threads_lands_on_node0() {
        let m = PageMap::first_touch(2, 10, &[0, 0]);
        assert_eq!(m.per_node(), &[10, 0]);
    }

    #[test]
    fn first_touch_single_node_takes_everything() {
        let m = PageMap::first_touch(1, 777, &[4]);
        assert_eq!(m.per_node(), &[777]);
        assert_eq!(m.fractions(), vec![1.0]);
        // Degenerate single-node machine with no threads yet.
        let m = PageMap::first_touch(1, 9, &[0]);
        assert_eq!(m.per_node(), &[9]);
    }

    #[test]
    fn first_touch_zero_pages_is_empty() {
        let m = PageMap::first_touch(2, 0, &[1, 1]);
        assert_eq!(m.total(), 0);
        assert_eq!(m.fractions(), vec![0.0, 0.0]);
    }

    #[test]
    fn fractions_sum_to_one() {
        let m = PageMap::first_touch(4, 999, &[1, 2, 3, 4]);
        let sum: f64 = m.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fractions_into_matches_allocating_variant() {
        let m = PageMap::first_touch(4, 999, &[1, 2, 3, 4]);
        let mut buf = vec![0.5; 9]; // stale, over-sized: must be reset
        m.fractions_into(&mut buf);
        assert_eq!(buf, m.fractions());
        let empty = PageMap::empty(3);
        empty.fractions_into(&mut buf);
        assert_eq!(buf, vec![0.0; 3]);
    }

    #[test]
    fn migrate_toward_respects_budget_and_conserves() {
        let mut m = PageMap::first_touch(4, 1000, &[1, 1, 1, 1]);
        let before = m.total();
        let moved = m.migrate_toward(0, 300);
        assert_eq!(moved, 300);
        assert_eq!(m.total(), before);
        assert_eq!(m.per_node()[0], 550);
        assert_eq!(m.migrated_total, 300);
        assert_eq!(m.migrate_ops, 300, "base pages: one op per page");
    }

    #[test]
    fn migrate_toward_stops_when_fully_local() {
        let mut m = PageMap::empty(2);
        m.per_node_mut()[0] = 100;
        let moved = m.migrate_toward(0, 1000);
        assert_eq!(moved, 0);
        assert_eq!(m.per_node()[0], 100);
    }

    #[test]
    fn migrate_from_single_origin() {
        let mut m = PageMap::empty(3);
        m.per_node_mut().copy_from_slice(&[50, 30, 20]);
        assert_eq!(m.migrate_from(1, 2, 100), 30);
        assert_eq!(m.per_node(), &[50, 0, 50]);
        assert_eq!(m.migrate_from(0, 0, 10), 0);
    }

    #[test]
    fn locality_extremes() {
        let mut m = PageMap::empty(2);
        m.per_node_mut().copy_from_slice(&[100, 0]);
        assert!((m.locality(&[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((m.locality(&[0.0, 1.0]) - 0.0).abs() < 1e-12);
    }

    // ------------------------------------------------------ tier tests

    #[test]
    fn promote_to_huge_respects_pool_and_conserves_bytes() {
        let mut m = PageMap::first_touch(2, 10_000, &[1, 0]);
        // Wants floor(10000*0.5)/512 = 9 huge pages; pool only has 4.
        let taken = m.promote_to_huge(0.5, &[4, 4]);
        assert_eq!(taken, vec![4, 0]);
        assert_eq!(m.huge_2m()[0], 4);
        assert_eq!(m.per_node()[0], 10_000 - 4 * 512);
        assert_eq!(m.total(), 10_000, "promotion conserves bytes");
        assert_eq!(m.mappings(), 10_000 - 4 * 512 + 4);
    }

    #[test]
    fn promote_to_huge_zero_frac_is_noop() {
        let mut m = PageMap::first_touch(2, 1000, &[1, 1]);
        assert_eq!(m.promote_to_huge(0.0, &[100, 100]), vec![0, 0]);
        assert_eq!(m.huge_2m(), &[0, 0]);
    }

    #[test]
    fn promote_to_giant_tier() {
        // 600k base pages: full eligibility is 2 whole 1 GiB pages.
        let mut m = PageMap::first_touch(2, 600_000, &[1, 0]);
        let taken = m.promote_to_tier(PageTier::Giant1G, 1.0, &[8, 8]);
        assert_eq!(taken, vec![2, 0]);
        assert_eq!(m.giant_1g()[0], 2);
        assert_eq!(m.per_node()[0], 600_000 - 2 * 262_144);
        assert_eq!(m.total(), 600_000);
        assert_eq!(m.mappings(), 600_000 - 2 * 262_144 + 2);
    }

    #[test]
    fn tiered_migration_prefers_big_pages_under_one_budget() {
        let mut m = PageMap::empty(2);
        m.per_node_mut()[1] = 2048; // 2048 base equivalents
        m.huge_2m_mut()[1] = 3; // 1536 equivalents in 3 ops
        let moved = m.migrate_toward(0, 2000);
        assert_eq!(moved, 2000);
        // All 3 huge pages moved first (1536 equiv, 3 ops), then 464
        // base pages (464 ops).
        assert_eq!(m.huge_2m()[0], 3);
        assert_eq!(m.per_node()[0], 464);
        assert_eq!(m.migrate_ops, 3 + 464);
        assert_eq!(m.migrated_total, 2000);
    }

    #[test]
    fn whole_pages_only_budget_below_tier_size() {
        let mut m = PageMap::empty(2);
        m.huge_2m_mut()[1] = 2;
        // Budget smaller than one huge page: nothing can move.
        assert_eq!(m.migrate_toward(0, 100), 0);
        assert_eq!(m.huge_2m(), &[0, 2]);
        assert_eq!(m.migrate_ops, 0);
    }

    #[test]
    fn tiered_migration_conserves_totals_across_tiers() {
        let mut m = PageMap::empty(3);
        m.per_node_mut().copy_from_slice(&[100, 700, 0]);
        m.huge_2m_mut().copy_from_slice(&[0, 2, 1]);
        let before = m.total();
        m.migrate_toward(0, 5_000);
        assert_eq!(m.total(), before);
        assert_eq!(m.node_total(1) + m.node_total(2), 0, "fully drained");
    }

    #[test]
    fn giant_pages_move_first_and_cost_one_op() {
        let mut m = PageMap::empty(2);
        m.giant_1g_mut()[1] = 1; // 262144 equivalents
        m.per_node_mut()[1] = 10;
        let moved = m.migrate_from(1, 0, 262_144);
        assert_eq!(moved, 262_144);
        assert_eq!(m.giant_1g()[0], 1);
        assert_eq!(m.per_node()[1], 10, "budget exhausted by the giant page");
        assert_eq!(m.migrate_ops, 1);
    }

    #[test]
    fn generation_tracks_mutation_and_fingerprint_tracks_content() {
        let mut m = PageMap::first_touch(2, 1000, &[1, 1]);
        let g0 = m.generation();
        let f0 = m.fingerprint();
        assert_eq!(m.migrate_toward(0, 0), 0, "zero budget moves nothing");
        assert_eq!(m.generation(), g0, "no move, no bump");
        assert_eq!(m.fingerprint(), f0);
        m.migrate_toward(0, 100);
        assert_ne!(m.generation(), g0);
        assert_ne!(m.fingerprint(), f0);
        // Direct writes bypass the counter but not the fingerprint —
        // even total-preserving permutations.
        let g1 = m.generation();
        let f1 = m.fingerprint();
        let (a, b) = (m.per_node()[0], m.per_node()[1]);
        m.per_node_mut().copy_from_slice(&[b, a]);
        assert_eq!(m.generation(), g1);
        assert_ne!(m.fingerprint(), f1);
        assert_eq!(m.epoch(), (m.generation(), m.fingerprint()));
    }

    #[test]
    fn tier_accessor_matches_named_slices() {
        let mut m = PageMap::empty(2);
        m.per_node_mut()[0] = 7;
        m.huge_2m_mut()[1] = 3;
        m.giant_1g_mut()[0] = 1;
        assert_eq!(m.tier(PageTier::Base4K), m.per_node());
        assert_eq!(m.tier(PageTier::Huge2M), m.huge_2m());
        assert_eq!(m.tier(PageTier::Giant1G), m.giant_1g());
    }

    #[test]
    fn node_total_mixes_tiers() {
        let mut m = PageMap::empty(2);
        m.per_node_mut()[0] = 7;
        m.huge_2m_mut()[0] = 2;
        m.giant_1g_mut()[0] = 1;
        assert_eq!(m.node_total(0), 7 + 1024 + 262_144);
        assert_eq!(m.total(), m.node_total(0));
    }
}
