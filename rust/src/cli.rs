//! Hand-rolled CLI (the vendor set has no `clap`).
//!
//! Subcommands mirror the experiment index:
//! `numasched run|table1|fig6|fig7|fig8|host-monitor|inspect [flags]`.

use std::path::PathBuf;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub config: Option<PathBuf>,
    pub seed: u64,
    pub seeds: Vec<u64>,
    pub horizon_ms: Option<f64>,
    pub policy: Option<String>,
    pub use_pjrt: bool,
    pub artifacts_dir: Option<String>,
    pub csv: bool,
    pub verbose: bool,
    /// Reduced-iteration mode for `bench-suite` (CI smoke).
    pub smoke: bool,
    /// `bench-suite`: smoke iterations plus a hard post-run validation
    /// of the scale tier (fleet dimensions, epoch-cache hits, sweep
    /// bit-identity) — the CI arm that guards the fleet-scale paths.
    pub scale_smoke: bool,
    /// Output file override (`bench-suite` writes BENCH_PERF.json here;
    /// `scenario record <name>` honors it for a single trace; `insight`
    /// writes its `numasched-insight/v1` JSON report here).
    pub out: Option<PathBuf>,
    /// Golden-trace directory for `scenario record|replay` (default
    /// `rust/tests/golden`).
    pub golden_dir: Option<PathBuf>,
    /// Write the run's metrics stream (`numasched-metrics/v1` JSONL)
    /// here; attaches telemetry to `run`, `scenario run|record`, and
    /// `explain`.
    pub metrics_out: Option<PathBuf>,
    /// Print the final Prometheus-style text exposition to stdout.
    pub metrics_text: bool,
    /// `lint` / `insight`: emit the machine-readable JSON report
    /// (`numasched-lint/v1` / `numasched-insight/v1`).
    pub json: bool,
    /// `insight bench`: fail (exit 1) on a confirmed perf regression
    /// once the history holds enough comparable entries.
    pub gate: bool,
    /// `insight bench`: history file (default `BENCH_HISTORY.jsonl`).
    pub history: Option<PathBuf>,
    /// `insight bench`: append this measured BENCH_PERF.json snapshot
    /// to the history before analyzing (provisional snapshots and
    /// duplicate run ids are skipped).
    pub append: Option<PathBuf>,
    /// `insight bench --append`: id recorded with the appended entry
    /// (CI passes the commit sha; default `local`).
    pub run_id: Option<String>,
    /// `insight bench`: noise-threshold override, e.g. `time=1.5,rate=0.8`.
    pub noise: Option<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

pub const USAGE: &str = "\
numasched — user-level NUMA-aware memory scheduler (paper reproduction)

USAGE:
    numasched <COMMAND> [FLAGS]

COMMANDS:
    run              run a workload under one policy (see --policy)
    table1           regenerate Table 1 (PARSEC characteristics)
    fig6             regenerate Figure 6 (degradation-factor accuracy)
    fig7             regenerate Figure 7 (speedup vs baselines, 40 cores)
    fig8             regenerate Figure 8 (Apache/MySQL throughput)
    ablate-hugepages sweep THP backing fraction (speedup + op savings)
    ablate-fabric    sweep hot-link bandwidth (fabric-aware vs blind placement)
    bench-suite      measure hot paths and write BENCH_PERF.json
    scenario         dynamic workload timelines:
                       scenario list              catalog of timelines
                       scenario run <name>        run one, print results
                       scenario record [name...]  write golden trace(s)
                       scenario replay [name...]  re-run + byte-diff traces
    chaos            deterministic fault injection:
                       chaos list               fault taxonomy + storm rates
                       chaos run [scenario]     run a timeline (default
                                                chaos-storm) under the storm
                                                and print recovery counters
                       chaos diff [scenario]    byte-diff a chaos-disabled run
                                                against one with no chaos
                                                layer at all (must be equal)
    explain          scheduler decision provenance:
                       explain <scenario> [filter]  run a timeline under the
                       proposed policy and print every placement, skip, and
                       consolidation with its candidate table (filter matches
                       outcome or comm, e.g. `skip:cooldown` or `canneal`)
    insight          cross-run analytics over recorded artifacts:
                       insight diff <a> <b>        align two runs (traces or
                                                   metrics streams), rank the
                                                   divergences, and report the
                                                   first decision split with
                                                   both candidate tables
                                                   (exit 1 when they diverge)
                       insight timeline <f> [pid]  stitch decisions, occupancy,
                                                   stale/quarantine transitions
                                                   and chaos faults from a
                                                   trace/metrics/flight file
                                                   into an ordered lifecycle
                       insight bench               trend BENCH_HISTORY.jsonl,
                                                   per-metric-family verdicts
                                                   (see --history / --append /
                                                   --noise / --gate)
    host-monitor     run the Monitor against this host's real /proc
    inspect          print machine presets and the workload catalog
    lint             determinism static analysis over rust/src (wall-clock
                     quarantine, NaN-safe ordering, panic-free parsers,
                     output hygiene, accessor discipline, structural sync);
                     `lint [paths...]` scopes the token rules to files/dirs;
                     exits 1 on violations (see --json)

FLAGS:
    --config <file>      TOML config (machine/scheduler/workloads)
    --seed <n>           experiment seed (default 42)
    --seeds <a,b,c>      multiple seeds (fig8 trials)
    --horizon <ms>       virtual-time horizon
    --policy <p>         default | autonuma | static | proposed
    --use-pjrt           score via AOT PJRT artifacts (default: pure Rust)
    --artifacts <dir>    artifact directory (default: artifacts)
    --csv                emit CSV instead of an ASCII table
    --smoke              bench-suite: reduced iterations (CI smoke mode)
    --scale-smoke        bench-suite: smoke mode + validate the 64node-fleet
                         scale tier (epoch-cache hits, sweep bit-identity);
                         exits nonzero when the tier is unhealthy
    --out <file>         bench-suite: output path (default BENCH_PERF.json);
                         insight: write the JSON report here as well
    --golden-dir <dir>   scenario: golden-trace dir (default rust/tests/golden)
    --metrics-out <file> write the metrics stream (numasched-metrics/v1 JSONL)
    --metrics-text       print the Prometheus-style exposition to stdout
    --json               lint / insight: machine-readable JSON report
                         (numasched-lint/v1 / numasched-insight/v1)
    --history <file>     insight bench: history file (default BENCH_HISTORY.jsonl)
    --append <file>      insight bench: append this measured BENCH_PERF.json
                         to the history first (provisional snapshots and
                         duplicate run ids are skipped)
    --run-id <id>        insight bench --append: entry id (default local)
    --noise <spec>       insight bench: thresholds, e.g. time=1.5,rate=0.8
                         (defaults time=1.35, rate=0.75)
    --gate               insight bench: exit 1 on a regression once >= 3
                         comparable history entries exist
    --verbose            debug logging
";

/// Parse argv (minus argv[0]). Returns Err(message) on bad input.
pub fn parse(args: &[String]) -> Result<Cli, String> {
    let mut cli = Cli { seed: 42, ..Default::default() };
    let mut it = args.iter().peekable();
    let Some(cmd) = it.next() else {
        return Err("missing command".into());
    };
    cli.command = cmd.clone();
    while let Some(arg) = it.next() {
        let mut value = |name: &str| -> Result<String, String> {
            it.next()
                .cloned()
                .ok_or_else(|| format!("{name} needs a value"))
        };
        match arg.as_str() {
            "--config" => cli.config = Some(PathBuf::from(value("--config")?)),
            "--seed" => {
                cli.seed = value("--seed")?
                    .parse()
                    .map_err(|_| "--seed must be an integer".to_string())?
            }
            "--seeds" => {
                cli.seeds = value("--seeds")?
                    .split(',')
                    .map(|s| s.trim().parse::<u64>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| "--seeds must be comma-separated integers".to_string())?
            }
            "--horizon" => {
                cli.horizon_ms = Some(
                    value("--horizon")?
                        .parse()
                        .map_err(|_| "--horizon must be a number".to_string())?,
                )
            }
            "--policy" => cli.policy = Some(value("--policy")?),
            "--use-pjrt" => cli.use_pjrt = true,
            "--artifacts" => cli.artifacts_dir = Some(value("--artifacts")?),
            "--csv" => cli.csv = true,
            "--smoke" => cli.smoke = true,
            "--scale-smoke" => {
                cli.smoke = true;
                cli.scale_smoke = true;
            }
            "--out" => cli.out = Some(PathBuf::from(value("--out")?)),
            "--golden-dir" => {
                cli.golden_dir = Some(PathBuf::from(value("--golden-dir")?))
            }
            "--metrics-out" => {
                cli.metrics_out = Some(PathBuf::from(value("--metrics-out")?))
            }
            "--metrics-text" => cli.metrics_text = true,
            "--json" => cli.json = true,
            "--gate" => cli.gate = true,
            "--history" => cli.history = Some(PathBuf::from(value("--history")?)),
            "--append" => cli.append = Some(PathBuf::from(value("--append")?)),
            "--run-id" => cli.run_id = Some(value("--run-id")?),
            "--noise" => cli.noise = Some(value("--noise")?),
            "--verbose" => cli.verbose = true,
            "--help" | "-h" => return Err(USAGE.to_string()),
            other if other.starts_with("--") => {
                return Err(format!("unknown flag {other} (try --help)"));
            }
            other => cli.positional.push(other.to_string()),
        }
    }
    Ok(cli)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_basic_command() {
        let c = parse(&argv("fig7 --seed 9 --use-pjrt")).unwrap();
        assert_eq!(c.command, "fig7");
        assert_eq!(c.seed, 9);
        assert!(c.use_pjrt);
        assert!(!c.csv);
    }

    #[test]
    fn parses_seeds_list() {
        let c = parse(&argv("fig8 --seeds 1,2,3")).unwrap();
        assert_eq!(c.seeds, vec![1, 2, 3]);
    }

    #[test]
    fn parses_policy_and_horizon() {
        let c = parse(&argv("run --policy autonuma --horizon 5000")).unwrap();
        assert_eq!(c.policy.as_deref(), Some("autonuma"));
        assert_eq!(c.horizon_ms, Some(5000.0));
    }

    #[test]
    fn rejects_unknown_flags() {
        assert!(parse(&argv("run --bogus")).is_err());
    }

    #[test]
    fn rejects_missing_values() {
        assert!(parse(&argv("run --seed")).is_err());
        assert!(parse(&argv("run --seed zebra")).is_err());
    }

    #[test]
    fn missing_command_errors() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn positional_collected() {
        let c = parse(&argv("inspect canneal")).unwrap();
        assert_eq!(c.positional, vec!["canneal"]);
    }

    #[test]
    fn parses_scenario_subcommands() {
        let c = parse(&argv("scenario replay phase-flip --golden-dir traces")).unwrap();
        assert_eq!(c.command, "scenario");
        assert_eq!(c.positional, vec!["replay", "phase-flip"]);
        assert_eq!(c.golden_dir, Some(PathBuf::from("traces")));
        assert!(parse(&argv("scenario record --golden-dir")).is_err());
    }

    #[test]
    fn parses_metrics_flags() {
        let c = parse(&argv(
            "scenario record link-storm --metrics-out m.jsonl --metrics-text",
        ))
        .unwrap();
        assert_eq!(c.metrics_out, Some(PathBuf::from("m.jsonl")));
        assert!(c.metrics_text);
        assert!(parse(&argv("run --metrics-out")).is_err());
    }

    #[test]
    fn parses_chaos_verb() {
        let c = parse(&argv("chaos run chaos-storm --seed 7 --metrics-out m.jsonl")).unwrap();
        assert_eq!(c.command, "chaos");
        assert_eq!(c.positional, vec!["run", "chaos-storm"]);
        assert_eq!(c.seed, 7);
        assert_eq!(c.metrics_out, Some(PathBuf::from("m.jsonl")));
        let c = parse(&argv("chaos diff")).unwrap();
        assert_eq!(c.positional, vec!["diff"]);
    }

    #[test]
    fn parses_explain_verb() {
        let c = parse(&argv("explain link-storm skip:cooldown")).unwrap();
        assert_eq!(c.command, "explain");
        assert_eq!(c.positional, vec!["link-storm", "skip:cooldown"]);
    }

    #[test]
    fn parses_bench_suite_flags() {
        let c = parse(&argv("bench-suite --smoke --out perf/B.json")).unwrap();
        assert_eq!(c.command, "bench-suite");
        assert!(c.smoke);
        assert!(!c.scale_smoke);
        assert_eq!(c.out, Some(PathBuf::from("perf/B.json")));
        assert!(parse(&argv("bench-suite --out")).is_err());
    }

    #[test]
    fn parses_lint_verb() {
        let c = parse(&argv("lint --json rust/src/reporter")).unwrap();
        assert_eq!(c.command, "lint");
        assert!(c.json);
        assert_eq!(c.positional, vec!["rust/src/reporter"]);
        let c = parse(&argv("lint")).unwrap();
        assert!(!c.json);
        assert!(c.positional.is_empty());
    }

    #[test]
    fn parses_insight_verb() {
        let c = parse(&argv("insight diff a.jsonl b.jsonl --json --out report.json")).unwrap();
        assert_eq!(c.command, "insight");
        assert_eq!(c.positional, vec!["diff", "a.jsonl", "b.jsonl"]);
        assert!(c.json);
        assert_eq!(c.out, Some(PathBuf::from("report.json")));

        let c = parse(&argv("insight timeline m.jsonl 42")).unwrap();
        assert_eq!(c.positional, vec!["timeline", "m.jsonl", "42"]);

        let c = parse(&argv(
            "insight bench --gate --history H.jsonl --append BENCH_PERF.json \
             --run-id abc123 --noise time=1.5,rate=0.8",
        ))
        .unwrap();
        assert_eq!(c.positional, vec!["bench"]);
        assert!(c.gate);
        assert_eq!(c.history, Some(PathBuf::from("H.jsonl")));
        assert_eq!(c.append, Some(PathBuf::from("BENCH_PERF.json")));
        assert_eq!(c.run_id.as_deref(), Some("abc123"));
        assert_eq!(c.noise.as_deref(), Some("time=1.5,rate=0.8"));
        assert!(parse(&argv("insight bench --history")).is_err());
        assert!(parse(&argv("insight bench --run-id")).is_err());
    }

    #[test]
    fn chaos_and_explain_accept_metrics_flags() {
        // Pins the telemetry surface parity: `chaos run` and `explain`
        // take the same --metrics-out/--metrics-text pair as `run`.
        let c = parse(&argv("chaos run link-storm --metrics-out c.jsonl --metrics-text")).unwrap();
        assert_eq!(c.metrics_out, Some(PathBuf::from("c.jsonl")));
        assert!(c.metrics_text);
        let c = parse(&argv("explain link-storm --metrics-out e.jsonl --metrics-text")).unwrap();
        assert_eq!(c.metrics_out, Some(PathBuf::from("e.jsonl")));
        assert!(c.metrics_text);
    }

    #[test]
    fn scale_smoke_implies_smoke() {
        let c = parse(&argv("bench-suite --scale-smoke")).unwrap();
        assert!(c.scale_smoke);
        assert!(c.smoke, "--scale-smoke must imply reduced iterations");
    }
}
