//! The Reporter — Algorithm 2 of the paper.
//!
//! > "Repeat until the runtime monitoring mechanism stops: receive data
//! >  and filter it, collect NUMA-specific data; if loading of the system
//! >  is unbalanced or behavior of the processes changed or a powerful
//! >  core [freed], compute the run-time speedup factor, sort the process
//! >  NUMA list by it, compute the contention degradation factor, sort
//! >  the process NUMA list by it, send the signal to trigger schedule."
//!
//! Concretely: the Reporter differences successive Monitor snapshots to
//! estimate per-node controller demand (from numastat deltas) and
//! per-task memory intensity (demand attributed by page share × CPU
//! rate), smooths them with EWMAs, detects the three trigger conditions,
//! and — when triggered — builds a `ScoreProblem`, scores it (AOT PJRT
//! artifact or the pure-Rust fallback), and emits a `Report` with the
//! sorted process NUMA lists for the Scheduler.

pub mod factors;

use std::collections::BTreeMap;

use crate::monitor::Snapshot;
use crate::runtime::pack::{pack, unpack, ScoreProblem, TaskRow};
use crate::runtime::{ScoreOutputs, ScoringEngine};
use crate::util::ewma::Ewma;
use crate::util::stats::cmp_f64_nan_low;

/// Why the Reporter fired (Algorithm 2's condition).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Triggers {
    /// Node demand imbalance above threshold.
    pub unbalanced: bool,
    /// A task's memory intensity or placement changed materially.
    pub behavior_changed: bool,
    /// A low-demand node has free capacity ("powerful core").
    pub powerful_core: bool,
}

impl Triggers {
    pub fn any(&self) -> bool {
        self.unbalanced || self.behavior_changed || self.powerful_core
    }
}

/// One entry of the sorted process NUMA list handed to the Scheduler.
#[derive(Clone, Debug)]
pub struct RankedTask {
    pub pid: i32,
    pub comm: String,
    pub node: usize,
    pub threads: i64,
    pub importance: f64,
    /// Estimated controller demand, GB/s.
    pub mem_intensity: f64,
    /// Contention degradation factor at the current placement.
    pub degradation: f64,
    /// Best candidate node and its speedup score.
    pub best_node: usize,
    pub best_score: f64,
    /// Full per-node score row.
    pub scores: Vec<f64>,
    /// Resident pages, 4 KiB equivalents (sticky-page migration sizing).
    pub rss_pages: u64,
    /// Per-node pages, 4 KiB equivalents.
    pub pages_per_node: Vec<u64>,
    /// Per-node 2 MiB huge pages (tier-aware freight estimation: a
    /// huge-backed working set migrates in far fewer operations).
    pub huge_2m_per_node: Vec<u64>,
    /// Per-node 1 GiB giant pages.
    pub giant_1g_per_node: Vec<u64>,
    /// True when the Monitor served this task from its last-good cache
    /// because the live reads are flapping — the coordinates may be
    /// arbitrarily old, so the Scheduler must not migrate on them.
    pub stale: bool,
}

/// The Reporter's output — Algorithm 2's "signal to trigger schedule".
#[derive(Clone, Debug)]
pub struct Report {
    pub t_ms: f64,
    pub triggers: Triggers,
    /// Tasks sorted by importance-weighted speedup factor (descending) —
    /// "sorting the process NUMA list by multi-core speedup factor".
    pub by_speedup: Vec<RankedTask>,
    /// Pids sorted by contention degradation factor (descending) —
    /// "sorting the process NUMA list by contention degradation factor".
    pub by_degradation: Vec<i32>,
    /// Node demand estimate, GB/s.
    pub node_demand: Vec<f64>,
    /// Node demand imbalance (max-min)/mean.
    pub imbalance: f64,
    /// Raw per-link fabric utilization, in the monitored source's link
    /// order (empty on fabric-less machines). The fabric-aware
    /// scheduler seeds its per-link projections from this; baselines
    /// ignore it.
    pub link_rho: Vec<f64>,
}

/// Per-pid tracked state (EWMA-smoothed estimates).
struct Tracked {
    cpu_ms_prev: u64,
    node_prev: usize,
    cpu_rate: Ewma,
    intensity: Ewma,
    /// Samples seen — behavior-change detection waits for the EWMAs to
    /// prime (the ramp-up itself must not read as a phase change).
    samples: u32,
}

/// Samples before behavior-change detection arms.
const PRIME_SAMPLES: u32 = 6;

/// Scoring backend selection.
pub enum Backend {
    /// Pure-Rust mirror of the kernel math.
    Cpu,
    /// AOT PJRT artifact (the three-layer hot path).
    Pjrt(Box<ScoringEngine>),
}

/// The Reporter.
pub struct Reporter {
    pub backend: Backend,
    /// Importance weights by comm (user-space knowledge the kernel lacks).
    pub importance: BTreeMap<String, f64>,
    /// Trigger thresholds (from `SchedulerConfig`).
    pub imbalance_threshold: f64,
    /// Relative intensity change that counts as "behavior changed".
    pub behavior_delta: f64,
    /// Node utilization below which a node offers "powerful cores".
    pub powerful_rho: f64,
    /// SLIT distance matrix and bandwidths (from Monitor discovery/config).
    pub distance: Vec<Vec<f64>>,
    pub bandwidth: Vec<f64>,

    tracked: BTreeMap<i32, Tracked>,
    node_served_prev: Vec<u64>,
    t_prev_ms: f64,
    half_life: f64,
    /// Set true whenever a fresh pid appears or one vanishes.
    roster_changed: bool,
}

impl Reporter {
    pub fn new(backend: Backend, distance: Vec<Vec<f64>>, bandwidth: Vec<f64>) -> Self {
        assert_eq!(distance.len(), bandwidth.len());
        Self {
            backend,
            importance: BTreeMap::new(),
            imbalance_threshold: 0.35,
            behavior_delta: 0.30,
            powerful_rho: 0.25,
            distance,
            bandwidth,
            tracked: BTreeMap::new(),
            node_served_prev: Vec::new(),
            t_prev_ms: f64::NAN,
            half_life: 4.0,
            roster_changed: false,
        }
    }

    pub fn nodes(&self) -> usize {
        self.bandwidth.len()
    }

    fn weight_of(&self, comm: &str) -> f64 {
        *self.importance.get(comm).unwrap_or(&1.0)
    }

    /// Ingest one snapshot. Returns a `Report` when at least two samples
    /// have been seen (rates need a delta) — the trigger decision is
    /// recorded inside, the Scheduler decides whether to act.
    pub fn ingest(&mut self, snap: &Snapshot) -> Option<Report> {
        let nodes = self.nodes();
        // ---- node demand from numastat deltas -------------------------
        let served: Vec<u64> = snap.nodes.iter().map(|n| n.total()).collect();
        let first = self.t_prev_ms.is_nan();
        let dt_ms = if first { 0.0 } else { (snap.t_ms - self.t_prev_ms).max(1e-9) };
        let node_demand: Vec<f64> = if first || self.node_served_prev.len() != nodes {
            vec![0.0; nodes]
        } else {
            served
                .iter()
                .zip(&self.node_served_prev)
                .map(|(&now, &prev)| {
                    // counter units: demand_GBs * 1000 per virtual ms.
                    (now.saturating_sub(prev)) as f64 / (dt_ms * 1000.0)
                })
                .collect()
        };
        self.node_served_prev = served;

        // ---- per-task attribution: mi[t] ------------------------------
        // Node n's demand is split across tasks proportionally to
        // pages_on_n × cpu_rate (a task that is asleep attracts nothing).
        let mut behavior_changed = false;
        let mut cpu_rate = BTreeMap::new();
        for task in &snap.tasks {
            let tr = self.tracked.entry(task.pid).or_insert_with(|| {
                self.roster_changed = true;
                Tracked {
                    cpu_ms_prev: task.cpu_ms,
                    node_prev: task.node,
                    cpu_rate: Ewma::with_half_life(self.half_life),
                    intensity: Ewma::with_half_life(self.half_life),
                    samples: 0,
                }
            });
            let rate = if first || dt_ms == 0.0 {
                0.0
            } else {
                (task.cpu_ms.saturating_sub(tr.cpu_ms_prev)) as f64 / dt_ms
            };
            tr.cpu_ms_prev = task.cpu_ms;
            let smoothed = tr.cpu_rate.update(rate);
            cpu_rate.insert(task.pid, smoothed.max(0.0));
            if tr.node_prev != task.node {
                behavior_changed = true; // OS moved the task under us
                tr.node_prev = task.node;
            }
        }
        // Drop vanished pids (set lookups — the same churn-pruning
        // idiom as the scheduler's placement ledger, not an O(n·m)
        // `Vec::contains` scan per sample).
        let live: std::collections::BTreeSet<i32> =
            snap.tasks.iter().map(|t| t.pid).collect();
        let before = self.tracked.len();
        self.tracked.retain(|pid, _| live.contains(pid));
        if self.tracked.len() != before {
            self.roster_changed = true;
        }

        let mut mi_new: BTreeMap<i32, f64> = BTreeMap::new();
        for n in 0..nodes {
            let weights: Vec<f64> = snap
                .tasks
                .iter()
                .map(|t| {
                    t.pages_per_node.get(n).copied().unwrap_or(0) as f64
                        * cpu_rate.get(&t.pid).copied().unwrap_or(0.0)
                })
                .collect();
            let total: f64 = weights.iter().sum();
            if total <= 0.0 {
                continue;
            }
            for (task, wgt) in snap.tasks.iter().zip(&weights) {
                *mi_new.entry(task.pid).or_insert(0.0) +=
                    node_demand[n] * wgt / total;
            }
        }
        for task in &snap.tasks {
            let tr = self.tracked.get_mut(&task.pid).unwrap();
            let raw = mi_new.get(&task.pid).copied().unwrap_or(0.0);
            let prev = tr.intensity.get();
            let smoothed = tr.intensity.update(raw);
            tr.samples += 1;
            if tr.samples > PRIME_SAMPLES
                && prev > 1e-3
                && (smoothed - prev).abs() / prev > self.behavior_delta
            {
                behavior_changed = true;
            }
        }

        self.t_prev_ms = snap.t_ms;
        if first {
            return None;
        }

        // ---- triggers --------------------------------------------------
        let mean = (node_demand.iter().sum::<f64>() / nodes as f64).max(1e-9);
        let max = node_demand.iter().copied().fold(f64::MIN, f64::max);
        let min = node_demand.iter().copied().fold(f64::MAX, f64::min);
        let imbalance = (max - min) / mean;
        let rho: Vec<f64> = node_demand
            .iter()
            .zip(&self.bandwidth)
            .map(|(d, b)| d / b)
            .collect();
        let triggers = Triggers {
            unbalanced: imbalance > self.imbalance_threshold,
            behavior_changed: behavior_changed || self.roster_changed,
            powerful_core: rho.iter().any(|&r| r < self.powerful_rho)
                && rho.iter().any(|&r| r > 2.0 * self.powerful_rho),
        };
        self.roster_changed = false;

        // ---- score -----------------------------------------------------
        let problem = ScoreProblem {
            tasks: snap
                .tasks
                .iter()
                .map(|t| TaskRow {
                    pid: t.pid,
                    pages_per_node: t
                        .pages_per_node
                        .iter()
                        .map(|&p| p as f64)
                        .collect(),
                    mem_intensity: self.tracked[&t.pid].intensity.get(),
                    importance: self.weight_of(&t.comm),
                    node: t.node,
                })
                .collect(),
            distance: self.distance.clone(),
            node_demand: node_demand.clone(),
            node_bandwidth: self.bandwidth.clone(),
        };
        let outputs = self.score(&problem)?;

        // ---- rank ("sorting the process NUMA list") ---------------------
        let mut by_speedup: Vec<RankedTask> = snap
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let scores = outputs.s[i].clone();
                let (best_node, best_score) = scores
                    .iter()
                    .enumerate()
                    .max_by(|a, b| cmp_f64_nan_low(*a.1, *b.1))
                    .map(|(n, &s)| (n, s))
                    .unwrap_or((t.node, 0.0));
                RankedTask {
                    pid: t.pid,
                    comm: t.comm.clone(),
                    node: t.node,
                    threads: t.threads,
                    importance: problem.tasks[i].importance,
                    mem_intensity: problem.tasks[i].mem_intensity,
                    degradation: outputs.degradation[i],
                    best_node,
                    best_score,
                    scores,
                    rss_pages: t.rss_pages,
                    pages_per_node: t.pages_per_node.clone(),
                    huge_2m_per_node: t.huge_2m_per_node.clone(),
                    giant_1g_per_node: t.giant_1g_per_node.clone(),
                    stale: t.stale_ticks > 0,
                }
            })
            .collect();
        rank_by_speedup(&mut by_speedup);
        let mut by_degradation: Vec<(i32, f64)> = by_speedup
            .iter()
            .map(|r| (r.pid, r.degradation))
            .collect();
        by_degradation.sort_by(|a, b| cmp_f64_nan_low(b.1, a.1));

        Some(Report {
            t_ms: snap.t_ms,
            triggers,
            by_speedup,
            by_degradation: by_degradation.into_iter().map(|(p, _)| p).collect(),
            node_demand,
            imbalance,
            link_rho: snap.links.iter().map(|l| l.rho).collect(),
        })
    }

    fn score(&self, problem: &ScoreProblem) -> Option<ScoreOutputs> {
        let t = problem.tasks.len();
        let n = problem.nodes();
        if t == 0 {
            return None;
        }
        let packed = pack(problem).ok()?;
        let raw = match &self.backend {
            Backend::Cpu => factors::score_cpu(&packed),
            Backend::Pjrt(engine) => engine.score(&packed).ok()?,
        };
        Some(unpack(&raw.s, &raw.dcur, &raw.r, &raw.c, t, n))
    }
}

/// Rank tasks for the report: descending best score, stable order.
/// NaN-safe: a poisoned score (NaN anywhere in the scoring pipeline)
/// must neither panic the sort nor outrank healthy rows — it compares
/// below every real value, and the stable sort keeps repeated runs
/// byte-identical.
fn rank_by_speedup(rows: &mut [RankedTask]) {
    rows.sort_by(|a, b| cmp_f64_nan_low(b.best_score, a.best_score));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{NodeSample, TaskSample};

    fn snap(t_ms: f64, tasks: Vec<TaskSample>, served: Vec<u64>) -> Snapshot {
        Snapshot {
            t_ms,
            tasks,
            nodes: served
                .into_iter()
                .map(|s| NodeSample { served_local: s, served_remote: 0 })
                .collect(),
            links: Vec::new(),
        }
    }

    fn task(pid: i32, node: usize, cpu_ms: u64, pages: Vec<u64>) -> TaskSample {
        TaskSample {
            pid,
            comm: format!("task{pid}"),
            node,
            threads: 1,
            cpu_ms,
            rss_pages: pages.iter().sum(),
            huge_2m_per_node: vec![0; pages.len()],
            giant_1g_per_node: vec![0; pages.len()],
            pages_per_node: pages,
            stale_ticks: 0,
        }
    }

    fn reporter() -> Reporter {
        Reporter::new(
            Backend::Cpu,
            vec![vec![10.0, 21.0], vec![21.0, 10.0]],
            vec![12.0, 12.0],
        )
    }

    fn ranked(pid: i32, best_score: f64) -> RankedTask {
        RankedTask {
            pid,
            comm: format!("task{pid}"),
            node: 0,
            threads: 1,
            importance: 1.0,
            mem_intensity: 0.0,
            degradation: 0.0,
            best_node: 0,
            best_score,
            scores: vec![best_score],
            rss_pages: 0,
            pages_per_node: vec![0, 0],
            huge_2m_per_node: vec![0, 0],
            giant_1g_per_node: vec![0, 0],
            stale: false,
        }
    }

    #[test]
    fn nan_scores_rank_last_and_never_panic() {
        // Regression: the speedup ranking used `partial_cmp(..).unwrap()`
        // and aborted the whole run on the first NaN score. A poisoned
        // row must sort *after* every healthy one, deterministically.
        let mut rows = vec![ranked(1, 0.5), ranked(2, f64::NAN), ranked(3, 1.2), ranked(4, 0.8)];
        rank_by_speedup(&mut rows);
        let pids: Vec<i32> = rows.iter().map(|r| r.pid).collect();
        assert_eq!(pids, vec![3, 4, 1, 2], "descending score, NaN last");
        // Same rows in a different arrival order agree exactly.
        let mut again = vec![ranked(2, f64::NAN), ranked(3, 1.2), ranked(4, 0.8), ranked(1, 0.5)];
        rank_by_speedup(&mut again);
        assert_eq!(again.iter().map(|r| r.pid).collect::<Vec<_>>(), pids);
    }

    #[test]
    fn first_snapshot_yields_no_report() {
        let mut r = reporter();
        assert!(r
            .ingest(&snap(0.0, vec![task(1, 0, 0, vec![100, 0])], vec![0, 0]))
            .is_none());
    }

    #[test]
    fn estimates_node_demand_from_deltas() {
        let mut r = reporter();
        r.ingest(&snap(0.0, vec![task(1, 0, 0, vec![100, 0])], vec![0, 0]));
        // 10 ms later: node 0 served 40_000 units = 4 GB/s.
        let rep = r
            .ingest(&snap(10.0, vec![task(1, 0, 10, vec![100, 0])], vec![40_000, 0]))
            .expect("report");
        assert!((rep.node_demand[0] - 4.0).abs() < 1e-9, "{:?}", rep.node_demand);
        assert_eq!(rep.node_demand[1], 0.0);
        assert!(rep.imbalance > 1.9, "one-sided load is imbalanced");
        assert!(rep.triggers.unbalanced);
    }

    #[test]
    fn attributes_intensity_to_the_active_task() {
        let mut r = reporter();
        let t0 = vec![
            task(1, 0, 0, vec![100, 0]),   // busy task
            task(2, 0, 0, vec![100, 0]),   // idle task (no cpu delta)
        ];
        r.ingest(&snap(0.0, t0, vec![0, 0]));
        let t1 = vec![
            task(1, 0, 10, vec![100, 0]),
            task(2, 0, 0, vec![100, 0]),
        ];
        let rep = r.ingest(&snap(10.0, t1, vec![20_000, 0])).unwrap();
        let r1 = rep.by_speedup.iter().find(|x| x.pid == 1).unwrap();
        let r2 = rep.by_speedup.iter().find(|x| x.pid == 2).unwrap();
        assert!(
            r1.mem_intensity > 10.0 * r2.mem_intensity.max(1e-12),
            "busy task should own the demand: {} vs {}",
            r1.mem_intensity,
            r2.mem_intensity
        );
    }

    #[test]
    fn misplaced_important_task_ranks_first() {
        let mut r = reporter();
        r.importance.insert("task1".into(), 5.0);
        // Task 1: on node 1, pages on node 0 (misplaced, important).
        // Task 2: on node 0, pages on node 0 (fine).
        let mk = |cpu: u64| {
            vec![
                task(1, 1, cpu, vec![500, 0]),
                task(2, 0, cpu, vec![500, 0]),
            ]
        };
        r.ingest(&snap(0.0, mk(0), vec![0, 0]));
        let rep = r.ingest(&snap(10.0, mk(10), vec![30_000, 0])).unwrap();
        assert_eq!(rep.by_speedup[0].pid, 1);
        assert_eq!(rep.by_speedup[0].best_node, 0, "wants to go to its pages");
        assert!(rep.by_speedup[0].best_score > 0.0);
        // Degradation ranking also puts the remote task first.
        assert_eq!(rep.by_degradation[0], 1);
    }

    #[test]
    fn behavior_change_triggers() {
        let mut r = reporter();
        let mk = |cpu, pages| vec![task(1, 0, cpu, pages)];
        r.ingest(&snap(0.0, mk(0, vec![100, 0]), vec![0, 0]));
        let rep = r.ingest(&snap(10.0, mk(10, vec![100, 0]), vec![10_000, 0])).unwrap();
        // First report: roster just changed (new pid) -> behavior trigger.
        assert!(rep.triggers.behavior_changed);
        // Steady state: no triggers.
        let rep = r
            .ingest(&snap(20.0, mk(20, vec![100, 0]), vec![20_000, 0]))
            .unwrap();
        assert!(!rep.triggers.behavior_changed, "steady state misfires");
        // Node switch (OS balancer moved it) -> behavior trigger.
        let moved = vec![task(1, 1, 30, vec![100, 0])];
        let rep = r.ingest(&snap(30.0, moved, vec![30_000, 0])).unwrap();
        assert!(rep.triggers.behavior_changed);
    }

    #[test]
    fn powerful_core_trigger_needs_asymmetry() {
        let mut r = reporter();
        let mk = |cpu| vec![task(1, 0, cpu, vec![100, 100])];
        r.ingest(&snap(0.0, mk(0), vec![0, 0]));
        // Node 0 hot (rho=0.8), node 1 idle (rho=0.05): powerful core free.
        let rep = r
            .ingest(&snap(10.0, mk(10), vec![96_000, 6_000]))
            .unwrap();
        assert!(rep.triggers.powerful_core);
        // Both busy: no powerful core.
        let rep = r
            .ingest(&snap(20.0, mk(20), vec![192_000, 102_000]))
            .unwrap();
        assert!(!rep.triggers.powerful_core);
    }

    #[test]
    fn stale_tag_propagates_to_ranked_tasks() {
        let mut r = reporter();
        r.ingest(&snap(0.0, vec![task(1, 0, 0, vec![100, 0])], vec![0, 0]));
        let mut t = task(1, 0, 10, vec![100, 0]);
        t.stale_ticks = 3; // monitor served its last-good copy
        let rep = r.ingest(&snap(10.0, vec![t], vec![10_000, 0])).unwrap();
        assert!(rep.by_speedup[0].stale, "staleness must reach the scheduler");
        let fresh = task(1, 0, 20, vec![100, 0]);
        let rep = r.ingest(&snap(20.0, vec![fresh], vec![20_000, 0])).unwrap();
        assert!(!rep.by_speedup[0].stale, "fresh samples clear the tag");
    }

    #[test]
    fn dead_pids_are_dropped() {
        let mut r = reporter();
        r.ingest(&snap(0.0, vec![task(1, 0, 0, vec![10, 0])], vec![0, 0]));
        r.ingest(&snap(10.0, vec![task(1, 0, 5, vec![10, 0])], vec![100, 0]));
        // Task 1 exits; task 2 appears.
        let rep = r
            .ingest(&snap(20.0, vec![task(2, 1, 0, vec![0, 10])], vec![200, 0]))
            .unwrap();
        assert_eq!(rep.by_speedup.len(), 1);
        assert_eq!(rep.by_speedup[0].pid, 2);
        assert!(rep.triggers.behavior_changed, "roster change flagged");
    }
}
