//! Pure-Rust scoring fallback — the same math as the Pallas kernel.
//!
//! Mirrors `python/compile/kernels/ref.py` (and therefore the AOT
//! artifact) bit-closely: computations run in f32 in the same order. The
//! Reporter uses this when `use_pjrt = false`, and the integration test
//! `rust/tests/hlo_equivalence.rs` asserts Rust == HLO on random
//! problems, pinning the L1/L2/L3 contract.

use crate::runtime::pack::{PackedInputs, NMAX, TMAX};
use crate::runtime::RawScores;

/// Model constants — the mirror of `python/compile/kernels/params.py`.
pub mod consts {
    pub const ALPHA: f32 = 1.0;
    pub const BETA: f32 = 1.0;
    pub const GAMMA: f32 = 0.02;
    pub const D_LOCAL: f32 = 10.0;
    pub const RHO_MAX: f32 = 0.95;
}

/// Score a packed problem on the CPU. Output layout matches
/// `ScoringEngine::score` exactly.
pub fn score_cpu(inp: &PackedInputs) -> RawScores {
    use consts::*;
    let mut s = vec![0.0f32; TMAX * NMAX];
    let mut dcur = vec![0.0f32; TMAX];
    let mut r_out = vec![0.0f32; TMAX * NMAX];
    let mut c_out = vec![0.0f32; TMAX * NMAX];

    for t in 0..TMAX {
        let a = &inp.a[t * NMAX..(t + 1) * NMAX];
        let cur = &inp.cur[t * NMAX..(t + 1) * NMAX];
        let mi = inp.mi[t];
        let w = inp.w[t];
        let mask = inp.mask[t];

        let rowsum: f32 = a.iter().sum();
        let denom = rowsum.max(1.0);

        // r[n] = rownorm(a) @ d[:, n]; loc/c per candidate node.
        let mut loc = [0.0f32; NMAX];
        let mut r_row = [0.0f32; NMAX];
        let mut c_row = [0.0f32; NMAX];
        for n in 0..NMAX {
            let mut r = 0.0f32;
            for m in 0..NMAX {
                r += (a[m] / denom) * inp.d[m * NMAX + n];
            }
            // Subtract the task's own measured traffic on n before adding
            // its demand at the candidate — mirror of
            // ref.contention_penalty (prevents self-contention phantoms).
            let u_bg = (inp.u[n] - mi * (a[n] / denom)).max(0.0);
            let rho = ((u_bg + mi) / inp.b[n]).clamp(0.0, RHO_MAX);
            let c = mi * rho / (1.0 - rho);
            loc[n] = ALPHA * (r - D_LOCAL) / D_LOCAL + BETA * c;
            r_row[n] = r;
            c_row[n] = c;
        }
        let d_cur: f32 = (0..NMAX).map(|n| loc[n] * cur[n]).sum();

        // Migration cost: gamma * log1p(pages) * (cur @ d / 10 - 1).
        let log_pages = rowsum.ln_1p();
        for n in 0..NMAX {
            let mut hop = 0.0f32;
            for m in 0..NMAX {
                hop += cur[m] * inp.d[m * NMAX + n];
            }
            let mig = GAMMA * log_pages * (hop / D_LOCAL - 1.0);
            s[t * NMAX + n] = (w * (d_cur - loc[n]) - mig) * mask;
            r_out[t * NMAX + n] = r_row[n] * mask;
            c_out[t * NMAX + n] = c_row[n] * mask;
        }
        dcur[t] = d_cur * mask;
    }
    RawScores { s, dcur, r: r_out, c: c_out }
}

/// Per-node demand / utilization / imbalance — mirror of
/// `ref.node_stats` (used when PJRT is off).
pub fn node_stats_cpu(inp: &PackedInputs) -> (Vec<f32>, Vec<f32>, f32) {
    let mut demand = vec![0.0f32; NMAX];
    for t in 0..TMAX {
        let a = &inp.a[t * NMAX..(t + 1) * NMAX];
        let rowsum: f32 = a.iter().sum();
        let denom = rowsum.max(1.0);
        for n in 0..NMAX {
            demand[n] += (a[n] / denom) * inp.mi[t];
        }
    }
    let rho: Vec<f32> = demand.iter().zip(&inp.b).map(|(d, b)| d / b).collect();
    let mean = (demand.iter().sum::<f32>() / NMAX as f32).max(1e-6);
    let max = demand.iter().copied().fold(f32::MIN, f32::max);
    let min = demand.iter().copied().fold(f32::MAX, f32::min);
    (demand.clone(), rho, (max - min) / mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pack::{pack, ScoreProblem, TaskRow};

    fn packed() -> PackedInputs {
        pack(&ScoreProblem {
            tasks: vec![
                TaskRow {
                    pid: 1,
                    pages_per_node: vec![800.0, 100.0],
                    mem_intensity: 1.2,
                    importance: 2.0,
                    node: 1,
                },
                TaskRow {
                    pid: 2,
                    pages_per_node: vec![0.0, 300.0],
                    mem_intensity: 0.3,
                    importance: 1.0,
                    node: 1,
                },
            ],
            distance: vec![vec![10.0, 21.0], vec![21.0, 10.0]],
            node_demand: vec![3.0, 1.0],
            node_bandwidth: vec![12.0, 12.0],
        })
        .unwrap()
    }

    #[test]
    fn staying_put_scores_zero() {
        let raw = score_cpu(&packed());
        // Task 0 currently on node 1: s[0][1] == 0.
        assert!(raw.s[1].abs() < 1e-6);
        assert!(raw.s[NMAX + 1].abs() < 1e-6);
    }

    #[test]
    fn misplaced_task_wants_to_go_home() {
        let raw = score_cpu(&packed());
        // Task 0's pages are mostly on node 0; moving there scores > 0.
        assert!(raw.s[0] > 0.0);
        // Task 1 is already local; moving away scores < 0.
        assert!(raw.s[NMAX] < 0.0);
    }

    #[test]
    fn degradation_positive_for_remote_task() {
        let raw = score_cpu(&packed());
        assert!(raw.dcur[0] > 0.0, "remote task must show degradation");
        assert!(raw.dcur[0] > raw.dcur[1], "local task degrades less");
    }

    #[test]
    fn masked_rows_zero() {
        let raw = score_cpu(&packed());
        for t in 2..TMAX {
            assert_eq!(raw.dcur[t], 0.0);
            assert!(raw.s[t * NMAX..(t + 1) * NMAX].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn importance_scales_score() {
        let mut inp = packed();
        let raw1 = score_cpu(&inp);
        inp.w[0] = 4.0; // double task 0's importance (was 2.0)
        let raw2 = score_cpu(&inp);
        // Score away from current node scales with w (mig term constant).
        let gain1 = raw1.s[0];
        let gain2 = raw2.s[0];
        assert!(gain2 > gain1 * 1.5, "w doubling: {gain1} -> {gain2}");
    }

    #[test]
    fn node_stats_attracts_demand_to_pages() {
        let (demand, rho, imb) = node_stats_cpu(&packed());
        assert!(demand[0] > 0.9, "task 0's intensity mostly on node 0");
        assert!(rho[0] > 0.0);
        assert!(imb > 0.0);
    }

    #[test]
    fn saturated_node_is_finite() {
        let mut inp = packed();
        inp.u[0] = 1e9;
        let raw = score_cpu(&inp);
        assert!(raw.s.iter().all(|x| x.is_finite()));
        assert!(raw.c.iter().all(|x| x.is_finite()));
    }
}
