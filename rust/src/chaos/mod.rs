//! Chaos engine — deterministic fault injection for the whole pipeline.
//!
//! The paper's scheduler lives on `/proc` and `migrate_pages(2)` — surfaces
//! that fail constantly on a real host: pids vanish mid-read, reads come
//! back truncated or corrupted, migrations return `EBUSY`/`ENOMEM` or land
//! partially, and whole nodes go offline. This module injects exactly those
//! faults, *deterministically*: every fault decision is a pure function of
//! `(seed, tick, pid, fault-kind)`, so a chaos run replays bit-identically
//! from its seed, and a failing storm shrinks to a reproducible case.
//!
//! Layering:
//! * [`ChaosConfig`] — rates per fault kind, parsed from a `[chaos]` config
//!   table or built via [`ChaosConfig::storm`].
//! * [`FaultPlan`] — the seeded decision engine plus the small amount of
//!   state faults need (vanish windows, offline windows, stale-text rings)
//!   and counters for every injected fault.
//! * [`FaultyProcSource`] / [`FaultyControl`] — wrappers around any
//!   `ProcSource` / `MachineControl` that consult the plan on every call.
//!
//! The wrappers are only constructed when chaos is enabled; a disabled
//! chaos config never touches the hot path, and the runner's no-chaos
//! code path is byte-identical to a build without this module.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, VecDeque};

use crate::procfs::ProcSource;
use crate::scheduler::{CtlError, MachineControl, MigrateOutcome};
use crate::util::rng::Rng;

/// Fault rates and windows. All `*_rate` fields are probabilities per
/// opportunity (per read, per control call, per node-tick) in `[0, 1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosConfig {
    /// Master switch. When false the runner must not construct any
    /// chaos wrapper at all (the disabled run is byte-identical to a
    /// run without chaos compiled in).
    pub enabled: bool,
    /// Chaos stream seed; 0 means "derive from the run seed".
    pub seed: u64,
    /// Whole procfs read returns `None` (EIO / vanished file).
    pub read_drop_rate: f64,
    /// Read returns a prefix of the real text (short read).
    pub read_truncate_rate: f64,
    /// Read returns deterministically mangled text (bit rot / torn read).
    pub read_corrupt_rate: f64,
    /// Read serves text captured `stale_depth` reads ago.
    pub read_stale_rate: f64,
    /// How many reads back the stale cache serves from.
    pub stale_depth: usize,
    /// Pid disappears from `list_pids` for `vanish_ticks` ticks while the
    /// process keeps running (the classic readdir race).
    pub pid_vanish_rate: f64,
    /// Duration of an injected vanish window, in plan ticks.
    pub vanish_ticks: u64,
    /// `move_process`/`migrate_pages` fails with `Busy`.
    pub migrate_busy_rate: f64,
    /// `move_process`/`migrate_pages` fails with `NoMem`.
    pub migrate_nomem_rate: f64,
    /// `migrate_pages` moves only part of the requested budget and
    /// reports the shortfall via [`MigrateOutcome`].
    pub migrate_partial_rate: f64,
    /// Per-tick probability of taking one node offline (at most one
    /// node is down at a time; node 0 is never taken down so the
    /// machine always has somewhere to run).
    pub node_offline_rate: f64,
    /// Duration of an offline window, in plan ticks.
    pub node_offline_ticks: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

impl ChaosConfig {
    /// All-zero, disabled config.
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            seed: 0,
            read_drop_rate: 0.0,
            read_truncate_rate: 0.0,
            read_corrupt_rate: 0.0,
            read_stale_rate: 0.0,
            stale_depth: 2,
            pid_vanish_rate: 0.0,
            vanish_ticks: 3,
            migrate_busy_rate: 0.0,
            migrate_nomem_rate: 0.0,
            migrate_partial_rate: 0.0,
            node_offline_rate: 0.0,
            node_offline_ticks: 40,
        }
    }

    /// The standard storm: every fault kind armed at production-plausible
    /// rates. This is what the `chaos` CLI verb and the chaos-storm
    /// scenario run.
    pub fn storm(seed: u64) -> Self {
        Self {
            enabled: true,
            seed,
            read_drop_rate: 0.02,
            read_truncate_rate: 0.02,
            read_corrupt_rate: 0.02,
            read_stale_rate: 0.03,
            stale_depth: 2,
            pid_vanish_rate: 0.01,
            vanish_ticks: 3,
            migrate_busy_rate: 0.10,
            migrate_nomem_rate: 0.05,
            migrate_partial_rate: 0.15,
            node_offline_rate: 0.002,
            node_offline_ticks: 60,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        let rates = [
            ("read_drop_rate", self.read_drop_rate),
            ("read_truncate_rate", self.read_truncate_rate),
            ("read_corrupt_rate", self.read_corrupt_rate),
            ("read_stale_rate", self.read_stale_rate),
            ("pid_vanish_rate", self.pid_vanish_rate),
            ("migrate_busy_rate", self.migrate_busy_rate),
            ("migrate_nomem_rate", self.migrate_nomem_rate),
            ("migrate_partial_rate", self.migrate_partial_rate),
            ("node_offline_rate", self.node_offline_rate),
        ];
        for (name, r) in rates {
            if !(0.0..=1.0).contains(&r) || !r.is_finite() {
                return Err(format!("chaos: {name} = {r} outside [0, 1]"));
            }
        }
        if self.stale_depth == 0 || self.stale_depth > 16 {
            return Err(format!(
                "chaos: stale_depth = {} outside 1..=16",
                self.stale_depth
            ));
        }
        Ok(())
    }
}

/// Counters for every injected fault, readable while the plan is shared
/// immutably (the `ProcSource` wrapper only ever sees `&self`).
#[derive(Debug, Default)]
pub struct ChaosStats {
    pub reads_dropped: Cell<u64>,
    pub reads_truncated: Cell<u64>,
    pub reads_corrupted: Cell<u64>,
    pub reads_stale: Cell<u64>,
    pub pids_vanished: Cell<u64>,
    pub migrate_busy: Cell<u64>,
    pub migrate_nomem: Cell<u64>,
    pub migrate_partial: Cell<u64>,
    pub moves_to_offline: Cell<u64>,
    pub node_offline_events: Cell<u64>,
    pub node_online_events: Cell<u64>,
}

impl ChaosStats {
    /// Total injected read faults (drop + truncate + corrupt + stale).
    pub fn reads_faulted(&self) -> u64 {
        self.reads_dropped.get()
            + self.reads_truncated.get()
            + self.reads_corrupted.get()
            + self.reads_stale.get()
    }

    /// Total injected migration faults (busy + nomem + partial + offline).
    pub fn migrations_faulted(&self) -> u64 {
        self.migrate_busy.get()
            + self.migrate_nomem.get()
            + self.migrate_partial.get()
            + self.moves_to_offline.get()
    }

    /// Grand total of injected faults of every kind.
    pub fn injected_total(&self) -> u64 {
        self.reads_faulted()
            + self.migrations_faulted()
            + self.pids_vanished.get()
            + self.node_offline_events.get()
            + self.node_online_events.get()
    }

    fn bump(cell: &Cell<u64>) {
        cell.set(cell.get() + 1);
    }
}

/// Distinct fault channels — mixed into the per-decision seed so each
/// kind draws from an independent stream.
#[derive(Clone, Copy)]
enum Channel {
    ReadDrop = 1,
    ReadTruncate = 2,
    ReadCorrupt = 3,
    ReadStale = 4,
    PidVanish = 5,
    Control = 6,
    NodeOffline = 7,
    Mangle = 8,
}

/// A node that just changed availability (reported by [`FaultPlan::begin_tick`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeTransition {
    pub node: usize,
    pub online: bool,
}

/// The seeded fault-decision engine.
///
/// Every decision is a pure function of `(seed, tick, entity, channel)` —
/// never of call order — so the allocating and zero-alloc monitor paths,
/// retries, and replays all see the same faults. The only mutable state
/// is what faults *require* (vanish windows, offline windows, stale-text
/// rings, a per-tick control-call sequence number) and it lives behind
/// `Cell`/`RefCell` because `ProcSource` methods take `&self`.
pub struct FaultPlan {
    cfg: ChaosConfig,
    seed: u64,
    nodes: usize,
    tick: Cell<u64>,
    /// Per-tick sequence number for control-plane calls (scheduler call
    /// order is deterministic, so this is too).
    ctl_seq: Cell<u64>,
    offline_until: RefCell<Vec<u64>>,
    vanished_until: RefCell<BTreeMap<i32, u64>>,
    stale_stat: RefCell<BTreeMap<i32, VecDeque<String>>>,
    stale_maps: RefCell<BTreeMap<i32, VecDeque<String>>>,
    pub stats: ChaosStats,
}

impl FaultPlan {
    /// Build a plan for a machine with `nodes` NUMA nodes. `run_seed` is
    /// the experiment seed; the chaos stream is decorrelated from it so
    /// chaos never perturbs workload generation.
    pub fn new(cfg: ChaosConfig, run_seed: u64, nodes: usize) -> Self {
        let seed = if cfg.seed != 0 {
            cfg.seed
        } else {
            run_seed ^ 0xC0A5_F00D_D15E_A5E5
        };
        Self {
            cfg,
            seed,
            nodes,
            tick: Cell::new(0),
            ctl_seq: Cell::new(0),
            offline_until: RefCell::new(vec![0; nodes]),
            vanished_until: RefCell::new(BTreeMap::new()),
            stale_stat: RefCell::new(BTreeMap::new()),
            stale_maps: RefCell::new(BTreeMap::new()),
            stats: ChaosStats::default(),
        }
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// One uniform draw on a channel, pure in (seed, tick, a, b, channel).
    fn draw(&self, ch: Channel, a: u64, b: u64) -> f64 {
        let mut mix = self.seed;
        mix ^= self.tick.get().wrapping_mul(0x9E37_79B9_7F4A_7C15);
        mix ^= a.wrapping_mul(0xA24B_AED4_963E_E407).rotate_left(17);
        mix ^= b.wrapping_mul(0x9E6C_63D0_876A_B6BD).rotate_left(31);
        mix ^= (ch as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
        Rng::new(mix).f64()
    }

    /// A forked rng for text mangling (needs several draws).
    fn mangle_rng(&self, pid: i32, kind: u64) -> Rng {
        let mut mix = self.seed ^ 0x5EED_0F4A_6713_D00D;
        mix ^= self.tick.get().wrapping_mul(0x9E37_79B9_7F4A_7C15);
        mix ^= (pid as u64).wrapping_mul(0xA24B_AED4_963E_E407);
        mix ^= kind.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        Rng::new(mix)
    }

    // ---- tick & node lifecycle ----------------------------------------

    /// Advance the plan clock. Returns node availability transitions that
    /// fire this tick (offline windows opening or expiring), for the
    /// runner to relay to the scheduler.
    pub fn begin_tick(&self, tick: u64) -> Vec<NodeTransition> {
        self.tick.set(tick);
        self.ctl_seq.set(0);
        let mut out = Vec::new();
        let mut until = self.offline_until.borrow_mut();
        let mut any_down = false;
        for (node, u) in until.iter_mut().enumerate() {
            if *u != 0 && *u <= tick {
                *u = 0;
                ChaosStats::bump(&self.stats.node_online_events);
                out.push(NodeTransition { node, online: true });
            }
            any_down |= *u != 0;
        }
        // At most one node down at a time, never node 0: the pipeline
        // must always have somewhere to evacuate to.
        if !any_down && self.nodes > 1 && self.cfg.node_offline_rate > 0.0 {
            if self.draw(Channel::NodeOffline, 0, 0) < self.cfg.node_offline_rate {
                let victim =
                    1 + (self.draw(Channel::NodeOffline, 1, 0) * (self.nodes - 1) as f64)
                        as usize;
                let victim = victim.min(self.nodes - 1);
                until[victim] = tick + self.cfg.node_offline_ticks.max(1);
                ChaosStats::bump(&self.stats.node_offline_events);
                out.push(NodeTransition { node: victim, online: false });
            }
        }
        out
    }

    pub fn is_offline(&self, node: usize) -> bool {
        self.offline_until
            .borrow()
            .get(node)
            .is_some_and(|&u| u != 0)
    }

    /// Nodes currently offline (for summaries/tests).
    pub fn offline_nodes(&self) -> Vec<usize> {
        self.offline_until
            .borrow()
            .iter()
            .enumerate()
            .filter(|(_, &u)| u != 0)
            .map(|(n, _)| n)
            .collect()
    }

    // ---- pid vanish ----------------------------------------------------

    /// Remove pids inside an injected vanish window, and roll new
    /// windows, in place (preserves order).
    fn filter_vanished(&self, pids: &mut Vec<i32>) {
        if self.cfg.pid_vanish_rate <= 0.0 {
            return;
        }
        let tick = self.tick.get();
        let mut windows = self.vanished_until.borrow_mut();
        windows.retain(|_, &mut u| u > tick);
        pids.retain(|&pid| {
            if windows.contains_key(&pid) {
                return false;
            }
            if self.draw(Channel::PidVanish, pid as u64, 0) < self.cfg.pid_vanish_rate {
                windows.insert(pid, tick + self.cfg.vanish_ticks.max(1));
                ChaosStats::bump(&self.stats.pids_vanished);
                return false;
            }
            true
        });
    }

    fn is_vanished(&self, pid: i32) -> bool {
        self.vanished_until
            .borrow()
            .get(&pid)
            .is_some_and(|&u| u > self.tick.get())
    }

    // ---- read mangling -------------------------------------------------

    /// Apply read faults to per-pid text. `kind` distinguishes the stat
    /// and numa_maps streams. Also maintains the stale-text ring.
    fn mangle_pid_read(
        &self,
        cache: &RefCell<BTreeMap<i32, VecDeque<String>>>,
        kind: u64,
        pid: i32,
        text: String,
    ) -> Option<String> {
        let key = pid as u64;
        if self.draw(Channel::ReadDrop, key, kind) < self.cfg.read_drop_rate {
            ChaosStats::bump(&self.stats.reads_dropped);
            return None;
        }
        // Serve stale text before updating the ring, so the served copy
        // really is from an older read.
        if self.draw(Channel::ReadStale, key, kind) < self.cfg.read_stale_rate {
            if let Some(ring) = cache.borrow().get(&pid) {
                if let Some(old) = ring.front() {
                    ChaosStats::bump(&self.stats.reads_stale);
                    return Some(old.clone());
                }
            }
        }
        {
            let mut cache = cache.borrow_mut();
            if cache.len() > 4096 {
                cache.clear(); // unbounded pid churn guard
            }
            let ring = cache.entry(pid).or_default();
            ring.push_back(text.clone());
            while ring.len() > self.cfg.stale_depth.max(1) {
                ring.pop_front();
            }
        }
        if self.draw(Channel::ReadTruncate, key, kind) < self.cfg.read_truncate_rate {
            ChaosStats::bump(&self.stats.reads_truncated);
            return Some(truncate_text(&text, self.mangle_rng(pid, kind ^ 1).f64()));
        }
        if self.draw(Channel::ReadCorrupt, key, kind) < self.cfg.read_corrupt_rate {
            ChaosStats::bump(&self.stats.reads_corrupted);
            return Some(corrupt_text(&text, &mut self.mangle_rng(pid, kind ^ 2)));
        }
        Some(text)
    }

    /// Apply read faults to node-level sysfs text (no stale ring; an
    /// offline node's files vanish outright).
    fn mangle_node_read(&self, kind: u64, node: usize, text: String) -> Option<String> {
        if self.is_offline(node) {
            return None;
        }
        let key = node as u64 ^ 0x4E0D_E000;
        if self.draw(Channel::ReadDrop, key, kind) < self.cfg.read_drop_rate {
            ChaosStats::bump(&self.stats.reads_dropped);
            return None;
        }
        if self.draw(Channel::ReadTruncate, key, kind) < self.cfg.read_truncate_rate {
            ChaosStats::bump(&self.stats.reads_truncated);
            return Some(truncate_text(&text, self.mangle_rng(node as i32, kind ^ 1).f64()));
        }
        if self.draw(Channel::ReadCorrupt, key, kind) < self.cfg.read_corrupt_rate {
            ChaosStats::bump(&self.stats.reads_corrupted);
            return Some(corrupt_text(&text, &mut self.mangle_rng(node as i32, kind ^ 2)));
        }
        Some(text)
    }

    // ---- control faults ------------------------------------------------

    /// Roll a control-plane fault for the next move/migrate call.
    fn control_fault(&self) -> Option<CtlError> {
        let seq = self.ctl_seq.get();
        self.ctl_seq.set(seq + 1);
        let d = self.draw(Channel::Control, seq, 0);
        if d < self.cfg.migrate_busy_rate {
            return Some(CtlError::Busy);
        }
        if d < self.cfg.migrate_busy_rate + self.cfg.migrate_nomem_rate {
            return Some(CtlError::NoMem);
        }
        None
    }

    /// Roll a partial-migration fraction for the next migrate call:
    /// `Some(frac)` means only `budget * frac` pages should move.
    fn partial_fraction(&self) -> Option<f64> {
        let seq = self.ctl_seq.get();
        if self.draw(Channel::Control, seq, 1) < self.cfg.migrate_partial_rate {
            // 25%..75% of the request lands.
            Some(0.25 + 0.5 * self.draw(Channel::Control, seq, 2))
        } else {
            None
        }
    }
}

/// Truncate at a char boundary near `frac` of the text.
fn truncate_text(text: &str, frac: f64) -> String {
    let mut cut = (text.len() as f64 * frac) as usize;
    while cut < text.len() && !text.is_char_boundary(cut) {
        cut += 1;
    }
    text[..cut.min(text.len())].to_string()
}

/// Deterministically mangle a window of the text (digits become junk,
/// separators survive — the shape a torn read or bit rot produces).
fn corrupt_text(text: &str, rng: &mut Rng) -> String {
    if text.is_empty() {
        return String::new();
    }
    let bytes = text.as_bytes();
    let start = rng.below(bytes.len());
    let len = 1 + rng.below(16.min(bytes.len()));
    let mut out = Vec::with_capacity(bytes.len());
    for (i, &b) in bytes.iter().enumerate() {
        if i >= start && i < start + len && b.is_ascii_alphanumeric() {
            out.push(b"#@!?%"[rng.below(5)]);
        } else {
            out.push(b);
        }
    }
    // ASCII-safe by construction (only ASCII bytes are replaced).
    String::from_utf8(out).unwrap_or_else(|_| text.to_string())
}

/// A `ProcSource` that filters every read through a [`FaultPlan`].
pub struct FaultyProcSource<'a> {
    inner: &'a dyn ProcSource,
    plan: &'a FaultPlan,
}

impl<'a> FaultyProcSource<'a> {
    pub fn new(inner: &'a dyn ProcSource, plan: &'a FaultPlan) -> Self {
        Self { inner, plan }
    }
}

const KIND_STAT: u64 = 0x57A7;
const KIND_MAPS: u64 = 0x4DA5;
const KIND_NUMASTAT: u64 = 0x4E57;
const KIND_LINKS: u64 = 0x11E6;

impl ProcSource for FaultyProcSource<'_> {
    fn list_pids(&self) -> Vec<i32> {
        let mut pids = self.inner.list_pids();
        self.plan.filter_vanished(&mut pids);
        pids
    }

    fn read_stat(&self, pid: i32) -> Option<String> {
        if self.plan.is_vanished(pid) {
            return None;
        }
        let text = self.inner.read_stat(pid)?;
        self.plan
            .mangle_pid_read(&self.plan.stale_stat, KIND_STAT, pid, text)
    }

    fn read_numa_maps(&self, pid: i32) -> Option<String> {
        if self.plan.is_vanished(pid) {
            return None;
        }
        let text = self.inner.read_numa_maps(pid)?;
        self.plan
            .mangle_pid_read(&self.plan.stale_maps, KIND_MAPS, pid, text)
    }

    // Topology discovery surfaces pass through un-mangled: discovery
    // happens once before the first tick, and a machine that cannot
    // enumerate its own nodes is dead, not degraded.
    fn read_nodes_online(&self) -> Option<String> {
        self.inner.read_nodes_online()
    }

    fn read_node_cpulist(&self, node: usize) -> Option<String> {
        self.inner.read_node_cpulist(node)
    }

    fn read_node_distance(&self, node: usize) -> Option<String> {
        self.inner.read_node_distance(node)
    }

    fn read_node_numastat(&self, node: usize) -> Option<String> {
        let text = self.inner.read_node_numastat(node)?;
        self.plan.mangle_node_read(KIND_NUMASTAT, node, text)
    }

    fn read_node_hugepage_file(
        &self,
        node: usize,
        tier_kb: u64,
        file: &str,
    ) -> Option<String> {
        self.inner.read_node_hugepage_file(node, tier_kb, file)
    }

    fn read_fabric_links(&self) -> Option<String> {
        let text = self.inner.read_fabric_links()?;
        self.plan.mangle_node_read(KIND_LINKS, 0, text)
    }
}

/// A `MachineControl` that filters every call through a [`FaultPlan`].
pub struct FaultyControl<'a> {
    inner: &'a mut dyn MachineControl,
    plan: &'a FaultPlan,
}

impl<'a> FaultyControl<'a> {
    pub fn new(inner: &'a mut dyn MachineControl, plan: &'a FaultPlan) -> Self {
        Self { inner, plan }
    }
}

impl MachineControl for FaultyControl<'_> {
    fn move_process(&mut self, pid: i32, node: usize) -> Result<(), CtlError> {
        if self.plan.is_offline(node) {
            ChaosStats::bump(&self.plan.stats.moves_to_offline);
            return Err(CtlError::NodeOffline);
        }
        match self.plan.control_fault() {
            Some(CtlError::Busy) => {
                ChaosStats::bump(&self.plan.stats.migrate_busy);
                Err(CtlError::Busy)
            }
            Some(CtlError::NoMem) => {
                ChaosStats::bump(&self.plan.stats.migrate_nomem);
                Err(CtlError::NoMem)
            }
            _ => self.inner.move_process(pid, node),
        }
    }

    fn migrate_pages(&mut self, pid: i32, node: usize, budget: u64) -> MigrateOutcome {
        if self.plan.is_offline(node) {
            ChaosStats::bump(&self.plan.stats.moves_to_offline);
            return MigrateOutcome::failed(CtlError::NodeOffline);
        }
        match self.plan.control_fault() {
            Some(CtlError::Busy) => {
                ChaosStats::bump(&self.plan.stats.migrate_busy);
                return MigrateOutcome::failed(CtlError::Busy);
            }
            Some(CtlError::NoMem) => {
                ChaosStats::bump(&self.plan.stats.migrate_nomem);
                return MigrateOutcome::failed(CtlError::NoMem);
            }
            _ => {}
        }
        if let Some(frac) = self.plan.partial_fraction() {
            let part = ((budget as f64) * frac) as u64;
            if part < budget {
                ChaosStats::bump(&self.plan.stats.migrate_partial);
                let inner = self.inner.migrate_pages(pid, node, part);
                return MigrateOutcome::partial(inner.moved, CtlError::Busy);
            }
        }
        self.inner.migrate_pages(pid, node, budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct FixedSource;

    impl ProcSource for FixedSource {
        fn list_pids(&self) -> Vec<i32> {
            (1..=64).collect()
        }
        fn read_stat(&self, pid: i32) -> Option<String> {
            Some(format!("{pid} (task{pid}) R 1 0 0 0 0 0 0 0 0 0 7 3"))
        }
        fn read_numa_maps(&self, _pid: i32) -> Option<String> {
            Some("00400000 default anon=100 N0=100 kernelpagesize_kB=4\n".into())
        }
        fn read_nodes_online(&self) -> Option<String> {
            Some("0-3".into())
        }
        fn read_node_cpulist(&self, _n: usize) -> Option<String> {
            Some("0-3".into())
        }
        fn read_node_distance(&self, _n: usize) -> Option<String> {
            Some("10 21 21 21".into())
        }
        fn read_node_numastat(&self, _n: usize) -> Option<String> {
            Some("numa_hit 100\nnuma_miss 5\n".into())
        }
    }

    struct NullCtl {
        moves: Vec<(i32, usize)>,
        pages: Vec<(i32, usize, u64)>,
    }

    impl MachineControl for NullCtl {
        fn move_process(&mut self, pid: i32, node: usize) -> Result<(), CtlError> {
            self.moves.push((pid, node));
            Ok(())
        }
        fn migrate_pages(&mut self, pid: i32, node: usize, budget: u64) -> MigrateOutcome {
            self.pages.push((pid, node, budget));
            MigrateOutcome::complete(budget)
        }
    }

    fn storm_plan() -> FaultPlan {
        FaultPlan::new(ChaosConfig::storm(7), 42, 4)
    }

    #[test]
    fn storm_config_validates() {
        assert!(ChaosConfig::storm(1).validate().is_ok());
        assert!(ChaosConfig::disabled().validate().is_ok());
        let mut bad = ChaosConfig::storm(1);
        bad.read_drop_rate = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = ChaosConfig::storm(1);
        bad.stale_depth = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn faults_are_deterministic_across_plans() {
        let run = |seed: u64| {
            let plan = FaultPlan::new(ChaosConfig::storm(seed), 42, 4);
            let src = FaultyProcSource::new(&FixedSource, &plan);
            let mut log = String::new();
            for tick in 0..50 {
                plan.begin_tick(tick);
                for pid in src.list_pids() {
                    match src.read_stat(pid) {
                        Some(s) => log.push_str(&s),
                        None => log.push('X'),
                    }
                    log.push('\n');
                }
            }
            (log, plan.stats.injected_total())
        };
        let (a, na) = run(7);
        let (b, nb) = run(7);
        assert_eq!(a, b, "same seed must inject identical faults");
        assert_eq!(na, nb);
        let (c, _) = run(8);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn storm_injects_every_read_fault_kind() {
        let plan = storm_plan();
        let src = FaultyProcSource::new(&FixedSource, &plan);
        for tick in 0..400 {
            plan.begin_tick(tick);
            for pid in src.list_pids() {
                let _ = src.read_stat(pid);
                let _ = src.read_numa_maps(pid);
            }
            for n in 0..4 {
                let _ = src.read_node_numastat(n);
            }
        }
        let s = &plan.stats;
        assert!(s.reads_dropped.get() > 0, "no dropped reads");
        assert!(s.reads_truncated.get() > 0, "no truncated reads");
        assert!(s.reads_corrupted.get() > 0, "no corrupted reads");
        assert!(s.reads_stale.get() > 0, "no stale reads");
        assert!(s.pids_vanished.get() > 0, "no vanishes");
    }

    #[test]
    fn storm_injects_control_faults() {
        let plan = storm_plan();
        let mut inner = NullCtl { moves: Vec::new(), pages: Vec::new() };
        let mut ctl = FaultyControl::new(&mut inner, &plan);
        let mut busy_or_nomem = 0;
        let mut partial = 0;
        for tick in 0..200 {
            plan.begin_tick(tick);
            for pid in 0..8 {
                if ctl.move_process(pid, 1).is_err() {
                    busy_or_nomem += 1;
                }
                let out = ctl.migrate_pages(pid, 1, 1000);
                if out.error.is_some() && out.moved > 0 {
                    partial += 1;
                    assert!(out.moved < 1000);
                }
            }
        }
        assert!(busy_or_nomem > 0, "no move faults injected");
        assert!(partial > 0, "no partial migrations injected");
        assert_eq!(
            plan.stats.migrate_busy.get()
                + plan.stats.migrate_nomem.get()
                + plan.stats.migrate_partial.get(),
            plan.stats.migrations_faulted()
        );
    }

    #[test]
    fn nodes_go_offline_and_come_back() {
        let plan = storm_plan();
        let mut saw_offline = false;
        let mut saw_online = false;
        for tick in 0..2000 {
            for tr in plan.begin_tick(tick) {
                assert_ne!(tr.node, 0, "node 0 must never go offline");
                if tr.online {
                    saw_online = true;
                } else {
                    saw_offline = true;
                    assert!(plan.is_offline(tr.node));
                    assert_eq!(plan.offline_nodes(), vec![tr.node]);
                }
            }
            assert!(
                plan.offline_nodes().len() <= 1,
                "at most one node down at a time"
            );
        }
        assert!(saw_offline, "no offline events in 2000 ticks");
        assert!(saw_online, "offline windows never expired");
        assert_eq!(
            plan.stats.node_offline_events.get(),
            plan.stats.node_online_events.get() + plan.offline_nodes().len() as u64,
        );
    }

    #[test]
    fn vanished_pids_return_after_window() {
        let cfg = ChaosConfig {
            pid_vanish_rate: 0.5,
            vanish_ticks: 2,
            ..ChaosConfig::storm(3)
        };
        let plan = FaultPlan::new(cfg, 42, 4);
        let src = FaultyProcSource::new(&FixedSource, &plan);
        plan.begin_tick(0);
        let gone: Vec<i32> = {
            let seen = src.list_pids();
            (1..=64).filter(|p| !seen.contains(p)).collect()
        };
        assert!(!gone.is_empty(), "vanish rate 0.5 hid nobody");
        for &pid in &gone {
            assert!(src.read_stat(pid).is_none(), "vanished pid still readable");
        }
        // Windows are bounded: within 200 ticks every victim has
        // reappeared at least once (it may vanish again on later rolls).
        let mut reappeared: std::collections::BTreeSet<i32> =
            std::collections::BTreeSet::new();
        for tick in 1..200 {
            plan.begin_tick(tick);
            let seen = src.list_pids();
            for &pid in &gone {
                if seen.contains(&pid) {
                    reappeared.insert(pid);
                }
            }
        }
        assert_eq!(reappeared.len(), gone.len(), "some pid never came back");
    }

    #[test]
    fn stale_reads_serve_older_text() {
        let cfg = ChaosConfig {
            read_stale_rate: 1.0,
            read_drop_rate: 0.0,
            read_truncate_rate: 0.0,
            read_corrupt_rate: 0.0,
            pid_vanish_rate: 0.0,
            ..ChaosConfig::storm(5)
        };
        struct Counter(Cell<u64>);
        impl ProcSource for Counter {
            fn list_pids(&self) -> Vec<i32> {
                vec![1]
            }
            fn read_stat(&self, _pid: i32) -> Option<String> {
                self.0.set(self.0.get() + 1);
                Some(format!("read-{}", self.0.get()))
            }
            fn read_numa_maps(&self, _pid: i32) -> Option<String> {
                None
            }
            fn read_nodes_online(&self) -> Option<String> {
                None
            }
            fn read_node_cpulist(&self, _n: usize) -> Option<String> {
                None
            }
            fn read_node_distance(&self, _n: usize) -> Option<String> {
                None
            }
            fn read_node_numastat(&self, _n: usize) -> Option<String> {
                None
            }
        }
        let plan = FaultPlan::new(cfg, 42, 2);
        let counter = Counter(Cell::new(0));
        let src = FaultyProcSource::new(&counter, &plan);
        plan.begin_tick(0);
        let first = src.read_stat(1).unwrap();
        assert_eq!(first, "read-1", "empty ring serves fresh text");
        plan.begin_tick(1);
        let second = src.read_stat(1).unwrap();
        assert_eq!(second, "read-1", "rate-1.0 stale serves the older text");
        assert!(plan.stats.reads_stale.get() > 0);
    }

    #[test]
    fn zero_rates_are_transparent() {
        let cfg = ChaosConfig { enabled: true, ..ChaosConfig::disabled() };
        let plan = FaultPlan::new(cfg, 42, 4);
        let src = FaultyProcSource::new(&FixedSource, &plan);
        let mut inner = NullCtl { moves: Vec::new(), pages: Vec::new() };
        for tick in 0..100 {
            assert!(plan.begin_tick(tick).is_empty());
            assert_eq!(src.list_pids(), FixedSource.list_pids());
            for pid in src.list_pids() {
                assert_eq!(src.read_stat(pid), FixedSource.read_stat(pid));
                assert_eq!(src.read_numa_maps(pid), FixedSource.read_numa_maps(pid));
            }
        }
        let mut ctl = FaultyControl::new(&mut inner, &plan);
        for pid in 0..32 {
            assert!(ctl.move_process(pid, 1).is_ok());
            assert_eq!(ctl.migrate_pages(pid, 1, 10).moved, 10);
        }
        assert_eq!(plan.stats.injected_total(), 0);
    }

    #[test]
    fn corrupt_and_truncate_are_utf8_safe() {
        let mut rng = Rng::new(1);
        let samples = ["", "a", "1234 (x) R 5 6", "N0=100 N1=200 kernelpagesize_kB=4"];
        for s in samples {
            for frac in [0.0, 0.3, 0.99, 1.0] {
                let t = truncate_text(s, frac);
                assert!(s.starts_with(&t));
            }
            if !s.is_empty() {
                let c = corrupt_text(s, &mut rng);
                assert_eq!(c.len(), s.len(), "corruption preserves length");
            }
        }
    }
}
