//! PJRT execution of the AOT artifacts — the L3↔L2 bridge.
//!
//! The real engine loads `artifacts/placement_score.hlo.txt` (HLO *text*;
//! see `python/compile/aot.py` for why not serialized protos), compiles it
//! once on the CPU PJRT client, and executes it on the Reporter's hot
//! path. That path needs the `xla` crate, which the offline build
//! environment does not vendor — so this module ships the same public
//! surface as a **stub**: manifest loading and contract checking are real,
//! but `load` reports that the PJRT backend is unavailable and callers
//! fall back to `reporter::Backend::Cpu`, whose `factors::score_cpu` is
//! the numerically-identical mirror of the kernel (pinned by
//! `rust/tests/hlo_equivalence.rs` when artifacts are present).
//!
//! Keeping the types (`ScoringEngine`, `RawScores`, `RawNodeStats`) stable
//! means the Reporter, the runner, and the benches compile and run
//! identically whether or not the accelerator path is vendored in.

use std::fmt;
use std::path::Path;

use super::manifest::Manifest;
use super::pack::PackedInputs;

/// Error type of the engine surface (stand-in for `anyhow::Error`).
#[derive(Clone, Debug)]
pub struct EngineError(pub String);

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for EngineError {}

pub type Result<T> = std::result::Result<T, EngineError>;

/// A compiled scoring engine bound to one PJRT client (stub: never
/// constructed in dependency-free builds).
pub struct ScoringEngine {
    pub manifest: Manifest,
}

/// Raw padded outputs of one scoring call.
#[derive(Clone, Debug)]
pub struct RawScores {
    pub s: Vec<f32>,    // (TMAX, NMAX)
    pub dcur: Vec<f32>, // (TMAX, 1)
    pub r: Vec<f32>,    // (TMAX, NMAX)
    pub c: Vec<f32>,    // (TMAX, NMAX)
}

/// Raw padded outputs of one node_stats call.
#[derive(Clone, Debug)]
pub struct RawNodeStats {
    pub demand: Vec<f32>, // (1, NMAX)
    pub rho: Vec<f32>,    // (1, NMAX)
    pub imbalance: f32,
}

const UNAVAILABLE: &str = "PJRT backend unavailable: the `xla` crate is \
not vendored in this build; use the pure-Rust scorer (Backend::Cpu), \
which mirrors the kernel math exactly";

impl ScoringEngine {
    /// Load and compile the artifacts in `dir`.
    ///
    /// The manifest contract is checked for real (so a bad artifact tree
    /// still fails loudly and early), then the stub reports that PJRT
    /// execution is not compiled in.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir).map_err(EngineError)?;
        manifest.check().map_err(EngineError)?;
        Err(EngineError(UNAVAILABLE.to_string()))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// One scoring epoch: padded inputs in, padded outputs out.
    pub fn score(&self, _inp: &PackedInputs) -> Result<RawScores> {
        Err(EngineError(UNAVAILABLE.to_string()))
    }

    /// Node-pressure summary (Reporter trigger input).
    pub fn node_stats(&self, _inp: &PackedInputs) -> Result<RawNodeStats> {
        Err(EngineError(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_errors_cleanly() {
        let Err(err) = ScoringEngine::load(Path::new("/nonexistent")) else {
            panic!("expected load failure");
        };
        let msg = format!("{err}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[test]
    fn valid_manifest_reports_pjrt_unavailable() {
        let dir = std::env::temp_dir()
            .join(format!("numasched-engine-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "tmax = 64\nnmax = 8\nd_local = 10.0\n\
             entry = placement_score inputs=8 outputs=4\n",
        )
        .unwrap();
        let Err(err) = ScoringEngine::load(&dir) else {
            panic!("stub must not construct an engine");
        };
        assert!(format!("{err}").contains("PJRT"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_manifest_contract_fails_before_the_stub_gate() {
        let dir = std::env::temp_dir()
            .join(format!("numasched-engine-bad-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "tmax = 32\nnmax = 8\nd_local = 10.0\n")
            .unwrap();
        let Err(err) = ScoringEngine::load(&dir) else {
            panic!("expected contract failure");
        };
        let msg = format!("{err}");
        assert!(msg.contains("artifact shape"), "{msg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
