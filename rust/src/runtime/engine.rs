//! PJRT execution of the AOT artifacts — the L3↔L2 bridge.
//!
//! Loads `artifacts/placement_score.hlo.txt` (HLO *text*; see
//! `python/compile/aot.py` for why not serialized protos), compiles it
//! once on the CPU PJRT client, and executes it on the Reporter's hot
//! path. Python is never involved at runtime.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use super::pack::{PackedInputs, NMAX, TMAX};

/// A compiled scoring engine bound to one PJRT client.
pub struct ScoringEngine {
    client: xla::PjRtClient,
    score_exe: xla::PjRtLoadedExecutable,
    node_stats_exe: Option<xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

/// Raw padded outputs of one scoring call.
#[derive(Clone, Debug)]
pub struct RawScores {
    pub s: Vec<f32>,    // (TMAX, NMAX)
    pub dcur: Vec<f32>, // (TMAX, 1)
    pub r: Vec<f32>,    // (TMAX, NMAX)
    pub c: Vec<f32>,    // (TMAX, NMAX)
}

/// Raw padded outputs of one node_stats call.
#[derive(Clone, Debug)]
pub struct RawNodeStats {
    pub demand: Vec<f32>, // (1, NMAX)
    pub rho: Vec<f32>,    // (1, NMAX)
    pub imbalance: f32,
}

impl ScoringEngine {
    /// Load and compile the artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        manifest.check().map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let score_exe = Self::compile(&client, &dir.join("placement_score.hlo.txt"))?;
        let node_stats_exe = if dir.join("node_stats.hlo.txt").exists() {
            Some(Self::compile(&client, &dir.join("node_stats.hlo.txt"))?)
        } else {
            None
        };
        Ok(Self { client, score_exe, node_stats_exe, manifest })
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn lit2(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(v.len(), rows * cols);
        Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
    }

    /// One scoring epoch: padded inputs in, padded outputs out.
    pub fn score(&self, inp: &PackedInputs) -> Result<RawScores> {
        let args = [
            Self::lit2(&inp.a, TMAX, NMAX)?,
            Self::lit2(&inp.d, NMAX, NMAX)?,
            Self::lit2(&inp.mi, TMAX, 1)?,
            Self::lit2(&inp.w, TMAX, 1)?,
            Self::lit2(&inp.u, 1, NMAX)?,
            Self::lit2(&inp.b, 1, NMAX)?,
            Self::lit2(&inp.cur, TMAX, NMAX)?,
            Self::lit2(&inp.mask, TMAX, 1)?,
        ];
        let result = self.score_exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 4 {
            return Err(anyhow!("placement_score returned {} outputs", parts.len()));
        }
        let mut it = parts.into_iter();
        Ok(RawScores {
            s: it.next().unwrap().to_vec::<f32>()?,
            dcur: it.next().unwrap().to_vec::<f32>()?,
            r: it.next().unwrap().to_vec::<f32>()?,
            c: it.next().unwrap().to_vec::<f32>()?,
        })
    }

    /// Node-pressure summary (Reporter trigger input).
    pub fn node_stats(&self, inp: &PackedInputs) -> Result<RawNodeStats> {
        let exe = self
            .node_stats_exe
            .as_ref()
            .ok_or_else(|| anyhow!("node_stats artifact not loaded"))?;
        let args = [
            Self::lit2(&inp.a, TMAX, NMAX)?,
            Self::lit2(&inp.mi, TMAX, 1)?,
            Self::lit2(&inp.b, 1, NMAX)?,
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            return Err(anyhow!("node_stats returned {} outputs", parts.len()));
        }
        let mut it = parts.into_iter();
        Ok(RawNodeStats {
            demand: it.next().unwrap().to_vec::<f32>()?,
            rho: it.next().unwrap().to_vec::<f32>()?,
            imbalance: it.next().unwrap().to_vec::<f32>()?[0],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pack::{pack, ScoreProblem, TaskRow};

    fn artifacts_dir() -> std::path::PathBuf {
        // Tests run from the crate root; `make artifacts` must have run.
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn sample_problem() -> ScoreProblem {
        ScoreProblem {
            tasks: vec![
                TaskRow {
                    pid: 1,
                    pages_per_node: vec![1000.0, 0.0, 0.0, 0.0],
                    mem_intensity: 2.0,
                    importance: 3.0,
                    node: 1, // running away from its pages
                },
                TaskRow {
                    pid: 2,
                    pages_per_node: vec![0.0, 500.0, 0.0, 0.0],
                    mem_intensity: 0.2,
                    importance: 1.0,
                    node: 1,
                },
            ],
            distance: vec![
                vec![10.0, 21.0, 21.0, 30.0],
                vec![21.0, 10.0, 30.0, 21.0],
                vec![21.0, 30.0, 10.0, 21.0],
                vec![30.0, 21.0, 21.0, 10.0],
            ],
            node_demand: vec![1.0, 2.0, 0.5, 0.5],
            node_bandwidth: vec![12.0; 4],
        }
    }

    #[test]
    fn loads_and_scores() {
        let eng = ScoringEngine::load(&artifacts_dir()).expect("load artifacts");
        let packed = pack(&sample_problem()).unwrap();
        let raw = eng.score(&packed).expect("score");
        assert_eq!(raw.s.len(), TMAX * NMAX);
        assert_eq!(raw.dcur.len(), TMAX);
        // Task 0 runs on node 1 but its pages are on node 0: moving to
        // node 0 must look strictly better than staying.
        assert!(raw.s[0] > 0.0, "s[0,0]={}", raw.s[0]);
        // Padded rows score exactly zero.
        assert!(raw.s[2 * NMAX..].iter().all(|&x| x == 0.0));
        // Staying put scores ~zero.
        assert!(raw.s[NMAX + 1].abs() < 1e-5);
    }

    #[test]
    fn node_stats_runs() {
        let eng = ScoringEngine::load(&artifacts_dir()).expect("load artifacts");
        let packed = pack(&sample_problem()).unwrap();
        let ns = eng.node_stats(&packed).expect("node_stats");
        assert_eq!(ns.demand.len(), NMAX);
        // Task demand is attracted to where pages are (nodes 0 and 1).
        assert!(ns.demand[0] > ns.demand[2]);
        assert!(ns.imbalance > 0.0);
    }

    #[test]
    fn missing_dir_errors_cleanly() {
        let Err(err) = ScoringEngine::load(Path::new("/nonexistent")) else {
            panic!("expected load failure");
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }
}
