//! Packing the Reporter's view into the AOT artifact's padded tensors.
//!
//! The artifact (`placement_score.hlo.txt`) is compiled once for a fixed
//! `(TMAX, NMAX)` problem; this module owns the padding contract (it
//! mirrors `python/compile/model.py::pad_inputs` exactly — the
//! cross-layer test in `rust/tests/hlo_equivalence.rs` pins them
//! together).

/// Maximum live tasks per scoring epoch (must match `params.TMAX`).
pub const TMAX: usize = 64;
/// Maximum NUMA nodes (must match `params.NMAX`).
pub const NMAX: usize = 8;

/// SLIT local distance.
pub const D_LOCAL: f32 = 10.0;
/// Utilization clip (mirror of `params.RHO_MAX`).
pub const RHO_MAX: f32 = 0.95;

/// One task's row in the scoring problem.
#[derive(Clone, Debug)]
pub struct TaskRow {
    pub pid: i32,
    /// Page heat per node (resident pages, optionally heat-weighted).
    pub pages_per_node: Vec<f64>,
    /// Estimated controller demand of this task, GB/s.
    pub mem_intensity: f64,
    /// User-space importance weight.
    pub importance: f64,
    /// Current home node.
    pub node: usize,
}

/// The unpadded scoring problem assembled by the Reporter.
#[derive(Clone, Debug)]
pub struct ScoreProblem {
    pub tasks: Vec<TaskRow>,
    /// SLIT distance matrix, row-major `nodes x nodes`.
    pub distance: Vec<Vec<f64>>,
    /// Controller demand per node, GB/s.
    pub node_demand: Vec<f64>,
    /// Controller bandwidth per node, GB/s.
    pub node_bandwidth: Vec<f64>,
}

impl ScoreProblem {
    pub fn nodes(&self) -> usize {
        self.distance.len()
    }
}

/// Flat padded tensors in artifact argument order.
#[derive(Clone, Debug, Default)]
pub struct PackedInputs {
    pub a: Vec<f32>,    // (TMAX, NMAX)
    pub d: Vec<f32>,    // (NMAX, NMAX)
    pub mi: Vec<f32>,   // (TMAX, 1)
    pub w: Vec<f32>,    // (TMAX, 1)
    pub u: Vec<f32>,    // (1, NMAX)
    pub b: Vec<f32>,    // (1, NMAX)
    pub cur: Vec<f32>,  // (TMAX, NMAX)
    pub mask: Vec<f32>, // (TMAX, 1)
}

/// Pad a problem to the artifact shape. Padding follows
/// `model.pad_inputs`: fake nodes get max distance, demand `RHO_MAX`, and
/// bandwidth 1 so they never attract tasks; padding tasks carry mask 0
/// and sit one-hot on node 0.
pub fn pack(p: &ScoreProblem) -> Result<PackedInputs, String> {
    let t = p.tasks.len();
    let n = p.nodes();
    if t > TMAX {
        return Err(format!("{t} tasks exceed TMAX={TMAX}"));
    }
    if n == 0 || n > NMAX {
        return Err(format!("{n} nodes out of 1..={NMAX}"));
    }
    let mut out = PackedInputs {
        a: vec![0.0; TMAX * NMAX],
        d: vec![4.0 * D_LOCAL; NMAX * NMAX],
        mi: vec![0.0; TMAX],
        w: vec![0.0; TMAX],
        u: vec![RHO_MAX; NMAX],
        b: vec![1.0; NMAX],
        cur: vec![0.0; TMAX * NMAX],
        mask: vec![0.0; TMAX],
    };
    for i in 0..NMAX {
        out.d[i * NMAX + i] = D_LOCAL;
    }
    for i in 0..n {
        for j in 0..n {
            out.d[i * NMAX + j] = p.distance[i][j] as f32;
        }
        out.u[i] = p.node_demand[i] as f32;
        out.b[i] = p.node_bandwidth[i] as f32;
    }
    // Padding tasks sit on node 0 (mask 0 zeroes their outputs anyway,
    // but cur must stay one-hot for the kernel's dot products).
    for ti in 0..TMAX {
        out.cur[ti * NMAX] = 1.0;
    }
    for (ti, task) in p.tasks.iter().enumerate() {
        if task.pages_per_node.len() != n {
            return Err(format!("task {ti} pages len != nodes"));
        }
        if task.node >= n {
            return Err(format!("task {ti} node {} out of range", task.node));
        }
        for ni in 0..n {
            out.a[ti * NMAX + ni] = task.pages_per_node[ni] as f32;
        }
        out.mi[ti] = task.mem_intensity as f32;
        out.w[ti] = task.importance as f32;
        out.cur[ti * NMAX] = 0.0;
        out.cur[ti * NMAX + task.node] = 1.0;
        out.mask[ti] = 1.0;
    }
    Ok(out)
}

/// Scoring outputs, unpadded back to the live problem size.
#[derive(Clone, Debug)]
pub struct ScoreOutputs {
    /// (tasks, nodes) placement scores.
    pub s: Vec<Vec<f64>>,
    /// Contention degradation factor per task.
    pub degradation: Vec<f64>,
    /// Mean access distance per (task, node).
    pub r: Vec<Vec<f64>>,
    /// Contention penalty per (task, node).
    pub c: Vec<Vec<f64>>,
}

/// Slice padded f32 outputs back down to `(t, n)`.
pub fn unpack(
    s: &[f32],
    dcur: &[f32],
    r: &[f32],
    c: &[f32],
    t: usize,
    n: usize,
) -> ScoreOutputs {
    let grab = |flat: &[f32]| -> Vec<Vec<f64>> {
        (0..t)
            .map(|ti| (0..n).map(|ni| flat[ti * NMAX + ni] as f64).collect())
            .collect()
    };
    ScoreOutputs {
        s: grab(s),
        degradation: (0..t).map(|ti| dcur[ti] as f64).collect(),
        r: grab(r),
        c: grab(c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem() -> ScoreProblem {
        ScoreProblem {
            tasks: vec![
                TaskRow {
                    pid: 1,
                    pages_per_node: vec![100.0, 0.0],
                    mem_intensity: 1.5,
                    importance: 2.0,
                    node: 0,
                },
                TaskRow {
                    pid: 2,
                    pages_per_node: vec![30.0, 70.0],
                    mem_intensity: 0.5,
                    importance: 1.0,
                    node: 1,
                },
            ],
            distance: vec![vec![10.0, 21.0], vec![21.0, 10.0]],
            node_demand: vec![4.0, 1.0],
            node_bandwidth: vec![12.0, 12.0],
        }
    }

    #[test]
    fn pack_shapes_and_mask() {
        let p = pack(&problem()).unwrap();
        assert_eq!(p.a.len(), TMAX * NMAX);
        assert_eq!(p.d.len(), NMAX * NMAX);
        assert_eq!(p.mask[..2], [1.0, 1.0]);
        assert_eq!(p.mask[2], 0.0);
        assert_eq!(p.a[0], 100.0);
        assert_eq!(p.a[NMAX + 1], 70.0);
    }

    #[test]
    fn pack_cur_is_one_hot_everywhere() {
        let p = pack(&problem()).unwrap();
        for ti in 0..TMAX {
            let row = &p.cur[ti * NMAX..(ti + 1) * NMAX];
            assert_eq!(row.iter().sum::<f32>(), 1.0, "row {ti}");
        }
        assert_eq!(p.cur[1], 0.0);
        assert_eq!(p.cur[NMAX + 1], 1.0); // task 1 on node 1
    }

    #[test]
    fn pack_padding_nodes_are_repellent() {
        let p = pack(&problem()).unwrap();
        // Fake node 5: saturated demand, unit bandwidth, max distance.
        assert_eq!(p.u[5], RHO_MAX);
        assert_eq!(p.b[5], 1.0);
        assert_eq!(p.d[5 * NMAX + 5], D_LOCAL);
        assert_eq!(p.d[2], 4.0 * D_LOCAL);
    }

    #[test]
    fn pack_rejects_oversize() {
        let mut p = problem();
        p.tasks = (0..TMAX + 1)
            .map(|i| TaskRow {
                pid: i as i32,
                pages_per_node: vec![1.0, 1.0],
                mem_intensity: 0.1,
                importance: 1.0,
                node: 0,
            })
            .collect();
        assert!(pack(&p).is_err());
    }

    #[test]
    fn pack_rejects_bad_rows() {
        let mut p = problem();
        p.tasks[0].pages_per_node = vec![1.0];
        assert!(pack(&p).is_err());
        let mut p = problem();
        p.tasks[0].node = 7;
        assert!(pack(&p).is_err());
    }

    #[test]
    fn unpack_slices_correctly() {
        let mut s = vec![0.0f32; TMAX * NMAX];
        s[0] = 1.0;
        s[NMAX + 1] = 2.0;
        let dcur = vec![0.5f32; TMAX];
        let out = unpack(&s, &dcur, &s, &s, 2, 2);
        assert_eq!(out.s.len(), 2);
        assert_eq!(out.s[0][0], 1.0);
        assert_eq!(out.s[1][1], 2.0);
        assert_eq!(out.degradation, vec![0.5, 0.5]);
    }
}
