//! Runtime bridge: load AOT-compiled HLO artifacts and execute them from
//! the Rust hot path via the `xla` crate's PJRT CPU client.
//!
//! * [`pack`] — the padding contract mirroring `model.pad_inputs`;
//! * [`manifest`] — artifact contract checking;
//! * [`engine`] — compile-once / execute-many scoring engine.

pub mod engine;
pub mod manifest;
pub mod pack;

pub use engine::{RawNodeStats, RawScores, ScoringEngine};
pub use pack::{pack, unpack, PackedInputs, ScoreOutputs, ScoreProblem, TaskRow};
