//! Parser for `artifacts/manifest.txt` — the contract emitted by
//! `python/compile/aot.py`. The runtime refuses to load artifacts whose
//! shapes or model constants disagree with this binary's compiled-in
//! expectations (a silent mismatch would corrupt every scoring epoch).

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub tmax: usize,
    pub nmax: usize,
    pub block_t: usize,
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub d_local: f64,
    pub rho_max: f64,
    pub vmem_bytes_per_step: u64,
    /// entry name -> (inputs, outputs)
    pub entries: BTreeMap<String, (usize, usize)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut m = Manifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(format!("bad manifest line: {line:?}"));
            };
            let key = key.trim();
            let val = val.trim();
            match key {
                "tmax" => m.tmax = parse_num(val)?,
                "nmax" => m.nmax = parse_num(val)?,
                "block_t" => m.block_t = parse_num(val)?,
                "alpha" => m.alpha = parse_f(val)?,
                "beta" => m.beta = parse_f(val)?,
                "gamma" => m.gamma = parse_f(val)?,
                "d_local" => m.d_local = parse_f(val)?,
                "rho_max" => m.rho_max = parse_f(val)?,
                "vmem_bytes_per_step" => m.vmem_bytes_per_step = parse_num(val)? as u64,
                "entry" => {
                    // "placement_score inputs=8 outputs=4"
                    let mut it = val.split_whitespace();
                    let name = it.next().ok_or("entry missing name")?.to_string();
                    let mut inputs = 0;
                    let mut outputs = 0;
                    for tok in it {
                        if let Some(v) = tok.strip_prefix("inputs=") {
                            inputs = parse_num(v)?;
                        } else if let Some(v) = tok.strip_prefix("outputs=") {
                            outputs = parse_num(v)?;
                        }
                    }
                    m.entries.insert(name, (inputs, outputs));
                }
                _ => {} // forward-compatible: ignore unknown keys
            }
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Assert the artifact contract matches this binary's constants.
    pub fn check(&self) -> Result<(), String> {
        use super::pack::{NMAX, TMAX};
        if self.tmax != TMAX || self.nmax != NMAX {
            return Err(format!(
                "artifact shape ({}, {}) != binary ({TMAX}, {NMAX}); re-run `make artifacts`",
                self.tmax, self.nmax
            ));
        }
        if (self.d_local - 10.0).abs() > 1e-9 {
            return Err("artifact d_local != 10".into());
        }
        if !self.entries.contains_key("placement_score") {
            return Err("manifest missing placement_score entry".into());
        }
        Ok(())
    }
}

fn parse_num(s: &str) -> Result<usize, String> {
    s.parse().map_err(|_| format!("bad number {s:?}"))
}

fn parse_f(s: &str) -> Result<f64, String> {
    s.parse().map_err(|_| format!("bad float {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# numasched AOT manifest
tmax = 64
nmax = 8
block_t = 16
alpha = 1.0
beta = 1.0
gamma = 0.02
d_local = 10.0
rho_max = 0.95
vmem_bytes_per_step = 5000
entry = placement_score inputs=8 outputs=4
entry = node_stats inputs=3 outputs=3
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tmax, 64);
        assert_eq!(m.nmax, 8);
        assert_eq!(m.gamma, 0.02);
        assert_eq!(m.entries["placement_score"], (8, 4));
        assert_eq!(m.entries["node_stats"], (3, 3));
        assert!(m.check().is_ok());
    }

    #[test]
    fn check_rejects_shape_mismatch() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.tmax = 32;
        assert!(m.check().is_err());
    }

    #[test]
    fn check_requires_placement_entry() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.entries.remove("placement_score");
        assert!(m.check().is_err());
    }

    #[test]
    fn bad_lines_error() {
        assert!(Manifest::parse("tmax 64").is_err());
        assert!(Manifest::parse("tmax = abc").is_err());
    }

    #[test]
    fn unknown_keys_ignored() {
        let m = Manifest::parse("tmax = 64\nnmax = 8\nfuture_knob = 3\n\
            d_local = 10.0\nentry = placement_score inputs=8 outputs=4")
            .unwrap();
        assert!(m.check().is_ok());
    }
}
