//! Baseline schedulers the paper compares against (Fig 7):
//! the OS default (first-touch, NUMA-blind balancing — i.e. doing
//! nothing beyond what `sim::Machine` already models), kernel Automatic
//! NUMA Balancing, and admin Static Tuning.

pub mod autonuma;
pub mod static_tuning;

pub use autonuma::AutoNuma;
