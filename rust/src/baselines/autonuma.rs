//! Simulated kernel Automatic NUMA Balancing (the Fig-7 "Automatic NUMA
//! Scheduling" baseline).
//!
//! Mechanism (mirroring the LKML v9 series the paper cites): the kernel
//! periodically unmaps ranges to provoke NUMA hinting faults, learns
//! which node a task actually runs on, and rate-limited-migrates its
//! pages toward that node; when most of a task's memory is remote it
//! also tries to move the *task* to its memory. Crucially it is blind to
//! user-space importance and to cross-application contention — exactly
//! the gap the paper's user-level scheduler fills.

use crate::sim::Machine;

/// The balancer's knobs (Linux defaults scaled to our virtual clock).
pub struct AutoNuma {
    /// Scan period, virtual ms (`numa_balancing_scan_period`).
    pub scan_ms: f64,
    /// Pages migrated per scan per process (rate limit).
    pub pages_per_scan: u64,
    /// Page fraction on one node above which the task follows its memory.
    pub task_follow_threshold: f64,
    last_scan_ms: f64,
}

impl AutoNuma {
    pub fn new(scan_ms: f64) -> Self {
        Self {
            scan_ms,
            pages_per_scan: 2560, // ~10 MB per scan: Linux's ratelimit scale
            // The kernel prefers whichever node accumulates the most
            // hinting faults — a plurality, not a supermajority.
            task_follow_threshold: 0.35,
            last_scan_ms: f64::NEG_INFINITY,
        }
    }

    /// Run one balancing opportunity; call every sim tick.
    pub fn step(&mut self, machine: &mut Machine) {
        if machine.now_ms - self.last_scan_ms < self.scan_ms {
            return;
        }
        self.last_scan_ms = machine.now_ms;

        let nodes = machine.topo.nodes;
        let cpn = machine.topo.cores_per_node;
        let pids = machine.running_pids();
        for pid in pids {
            let Some(p) = machine.process(pid) else { continue };
            // Where does the task run, where is its memory?
            let home = p.home_node(nodes, cpn);
            let fracs = p.pages.fractions();
            let (mem_node, mem_frac) = fracs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(n, &f)| (n, f))
                .unwrap_or((home, 0.0));

            if mem_node != home && mem_frac >= self.task_follow_threshold {
                // task_numa_migrate: move the task to its memory, and set
                // the numa-preferred node so the load balancer respects
                // it (the kernel's numa_preferred_nid bias).
                machine.pin_process(pid, mem_node);
            } else {
                // NUMA hinting faults: pull pages toward the CPU node,
                // rate-limited.
                let remote: u64 = p
                    .pages
                    .per_node
                    .iter()
                    .enumerate()
                    .filter(|&(n, _)| n != home)
                    .map(|(_, &c)| c)
                    .sum();
                if remote > 0 {
                    machine.migrate_pages(pid, home, self.pages_per_scan);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Placement, TaskBehavior};
    use crate::topology::NumaTopology;

    fn machine() -> Machine {
        let mut m = Machine::new(NumaTopology::r910_40core(), 3);
        m.os_balance = false;
        m
    }

    #[test]
    fn converges_task_and_pages_onto_one_node() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            // Strand most memory remotely.
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node = vec![total * 2 / 5, total - total * 2 / 5, 0, 0];
        }
        let mut an = AutoNuma::new(10.0);
        for _ in 0..2000 {
            an.step(&mut m);
            m.step();
        }
        // Wherever the balancer settled the task, its pages follow it.
        let p = m.process(pid).unwrap();
        let home = p.home_node(4, 10);
        let fr = p.pages.fractions();
        assert!(fr[home] > 0.95, "pages should converge to home {home}: {fr:?}");
    }

    #[test]
    fn follows_memory_when_mostly_remote() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node = vec![total / 10, 0, total - total / 10, 0];
        }
        let mut an = AutoNuma::new(10.0);
        an.step(&mut m); // immediate scan
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2, "task should follow its memory");
    }

    #[test]
    fn rate_limit_bounds_migration_volume() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node = vec![total / 2, total - total / 2, 0, 0];
        }
        let mut an = AutoNuma::new(10.0);
        an.step(&mut m);
        assert!(m.total_pages_migrated <= an.pages_per_scan);
    }

    #[test]
    fn idle_between_scans() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 1, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            p.pages.per_node = vec![500, 500, 0, 0];
        }
        let mut an = AutoNuma::new(100.0);
        an.step(&mut m); // scan at t=0
        let after_first = m.total_pages_migrated;
        m.step(); // t=1ms
        an.step(&mut m); // within the period: no work
        assert_eq!(m.total_pages_migrated, after_first);
    }
}
