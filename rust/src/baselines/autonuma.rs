//! Simulated kernel Automatic NUMA Balancing (the Fig-7 "Automatic NUMA
//! Scheduling" baseline).
//!
//! Mechanism (mirroring the LKML v9 series the paper cites): the kernel
//! periodically unmaps ranges to provoke NUMA hinting faults, learns
//! which node a task actually runs on, and rate-limited-migrates its
//! pages toward that node; when most of a task's memory is remote it
//! also tries to move the *task* to its memory. Crucially it is blind to
//! user-space importance and to cross-application contention — exactly
//! the gap the paper's user-level scheduler fills.
//!
//! Capacity, however, is no longer invisible: the balancer shares the
//! scheduler's [`PlacementLedger`], so a task-follow that would
//! overcommit a node's powerful-core slots with already-placed tasks
//! falls back to pulling pages instead (Durbhakula, arXiv 1809.08628:
//! capacity-blind migration erases NUMA gains). All three policies in
//! the differential suite therefore account occupancy the same way.

use std::collections::BTreeSet;

use crate::scheduler::PlacementLedger;
use crate::sim::Machine;
use crate::topology::NumaTopology;
use crate::util::stats::cmp_f64_nan_low;

/// The balancer's knobs (Linux defaults scaled to our virtual clock).
pub struct AutoNuma {
    /// Scan period, virtual ms (`numa_balancing_scan_period`).
    pub scan_ms: f64,
    /// Pages migrated per scan per process (rate limit).
    pub pages_per_scan: u64,
    /// Page fraction on one node above which the task follows its memory.
    pub task_follow_threshold: f64,
    last_scan_ms: f64,
    /// Shared occupancy accounting (tasks this balancer has placed).
    ledger: PlacementLedger,
}

impl AutoNuma {
    pub fn new(scan_ms: f64, topo: &NumaTopology) -> Self {
        Self {
            scan_ms,
            pages_per_scan: 2560, // ~10 MB per scan: Linux's ratelimit scale
            // The kernel prefers whichever node accumulates the most
            // hinting faults — a plurality, not a supermajority.
            task_follow_threshold: 0.35,
            last_scan_ms: f64::NEG_INFINITY,
            ledger: PlacementLedger::from_topology(topo),
        }
    }

    /// The shared occupancy view (read-only).
    pub fn ledger(&self) -> &PlacementLedger {
        &self.ledger
    }

    /// Crate-internal mutable access for the runner's churn routing.
    pub(crate) fn ledger_mut(&mut self) -> &mut PlacementLedger {
        &mut self.ledger
    }

    /// A pid exited (`Machine::kill` via the runner's event drain).
    pub fn observe_exit(&mut self, pid: i32) {
        self.ledger.on_exit(pid);
    }

    /// A pid appeared (fork/launch): clear recycled-pid leftovers.
    pub fn observe_spawn(&mut self, pid: i32) {
        self.ledger.on_spawn(pid);
    }

    /// Run one balancing opportunity; call every sim tick.
    pub fn step(&mut self, machine: &mut Machine) {
        if machine.now_ms - self.last_scan_ms < self.scan_ms {
            return;
        }
        self.last_scan_ms = machine.now_ms;

        let nodes = machine.topo.nodes;
        let cpn = machine.topo.cores_per_node;
        let live: BTreeSet<i32> = machine.running_pid_set();
        self.ledger.sync_live(&live);
        let total_threads: i64 = live
            .iter()
            .filter_map(|&pid| machine.process(pid))
            .map(|p| p.nthreads() as i64)
            .sum();
        let thread_cap = self.ledger.thread_cap(total_threads);
        for &pid in &live {
            let Some(p) = machine.process(pid) else { continue };
            // Where does the task run, where is its memory?
            let home = p.home_node(nodes, cpn);
            let threads = p.nthreads() as i64;
            let fracs = p.pages.fractions();
            let (mem_node, mem_frac) = fracs
                .iter()
                .enumerate()
                .max_by(|a, b| cmp_f64_nan_low(*a.1, *b.1))
                .map(|(n, &f)| (n, f))
                .unwrap_or((home, 0.0));

            // A task re-affirming its own placement always fits; anyone
            // else must find free powerful-core slots on the target.
            let follow_fits = match self.ledger.placement(pid) {
                Some(pl) if pl.node == mem_node => true,
                _ => self.ledger.fits(mem_node, threads, thread_cap),
            };
            if mem_node != home && mem_frac >= self.task_follow_threshold && follow_fits {
                // task_numa_migrate: move the task to its memory, and set
                // the numa-preferred node so the load balancer respects
                // it (the kernel's numa_preferred_nid bias).
                machine.pin_process(pid, mem_node);
                self.ledger.record_placement(pid, mem_node, threads, false);
            } else {
                // NUMA hinting faults: pull pages toward the CPU node,
                // rate-limited. (Also the fallback when the follow would
                // overcommit the memory node's slots.)
                let remote: u64 = p
                    .pages
                    .per_node()
                    .iter()
                    .enumerate()
                    .filter(|&(n, _)| n != home)
                    .map(|(_, &c)| c)
                    .sum();
                if remote > 0 {
                    machine.migrate_pages(pid, home, self.pages_per_scan);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Placement, TaskBehavior};
    use crate::topology::NumaTopology;

    fn machine() -> Machine {
        let mut m = Machine::new(NumaTopology::r910_40core(), 3);
        m.os_balance = false;
        m
    }

    #[test]
    fn converges_task_and_pages_onto_one_node() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            // Strand most memory remotely.
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node_mut().copy_from_slice(&[total * 2 / 5, total - total * 2 / 5, 0, 0]);
        }
        let mut an = AutoNuma::new(10.0, &m.topo);
        for _ in 0..2000 {
            an.step(&mut m);
            m.step();
        }
        // Wherever the balancer settled the task, its pages follow it.
        let p = m.process(pid).unwrap();
        let home = p.home_node(4, 10);
        let fr = p.pages.fractions();
        assert!(fr[home] > 0.95, "pages should converge to home {home}: {fr:?}");
    }

    #[test]
    fn follows_memory_when_mostly_remote() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node_mut().copy_from_slice(&[total / 10, 0, total - total / 10, 0]);
        }
        let mut an = AutoNuma::new(10.0, &m.topo);
        an.step(&mut m); // immediate scan
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2, "task should follow its memory");
    }

    #[test]
    fn rate_limit_bounds_migration_volume() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node_mut().copy_from_slice(&[total / 2, total - total / 2, 0, 0]);
        }
        let mut an = AutoNuma::new(10.0, &m.topo);
        an.step(&mut m);
        assert!(m.total_pages_migrated <= an.pages_per_scan);
    }

    #[test]
    fn task_follow_is_capacity_gated_by_the_shared_ledger() {
        // Three 4-thread tasks on node 0, all with memory stranded on
        // node 2. thread_cap = ceil(12/4) + 10*0.2 = 5: the first follow
        // fits (4 <= 5), the rest would overcommit node 2 and must fall
        // back to pulling pages home instead of stacking tasks.
        let mut m = machine();
        let mut pids = Vec::new();
        for i in 0..3 {
            let pid = m.spawn(
                &format!("w{i}"),
                TaskBehavior::mem_bound(1e9),
                1.0,
                4,
                Placement::Node(0),
            );
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node_mut().copy_from_slice(&[0, 0, total, 0]);
            pids.push(pid);
        }
        let mut an = AutoNuma::new(10.0, &m.topo);
        an.step(&mut m);
        let homes: Vec<usize> = pids
            .iter()
            .map(|&p| m.process(p).unwrap().home_node(4, 10))
            .collect();
        assert_eq!(homes[0], 2, "first follow fits the slots");
        assert_eq!(homes[1], 0, "second follow would overcommit — blocked");
        assert_eq!(homes[2], 0, "third follow blocked too");
        assert_eq!(an.ledger().occupied(2), 4, "one placed task on node 2");
        assert!(
            m.total_pages_migrated > 0,
            "blocked tasks still pull pages toward home"
        );
        an.ledger()
            .check_invariants(&pids.iter().copied().collect())
            .unwrap();
    }

    #[test]
    fn ledger_prunes_dead_pids_between_scans() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 2, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            let total = p.pages.total();
            p.pages.per_node_mut().copy_from_slice(&[0, 0, total, 0]);
        }
        let mut an = AutoNuma::new(10.0, &m.topo);
        an.step(&mut m);
        assert!(an.ledger().placement(pid).is_some());
        m.kill(pid);
        an.observe_exit(pid); // the runner's wiring
        assert!(an.ledger().placement(pid).is_none());
        assert_eq!(an.ledger().occupied(2), 0);
        an.ledger().check_invariants(&Default::default()).unwrap();
    }

    #[test]
    fn idle_between_scans() {
        let mut m = machine();
        let pid = m.spawn("w", TaskBehavior::mem_bound(1e9), 1.0, 1, Placement::Node(0));
        {
            let p = m.process_mut(pid).unwrap();
            p.pages.per_node_mut().copy_from_slice(&[500, 500, 0, 0]);
        }
        let mut an = AutoNuma::new(100.0, &m.topo);
        an.step(&mut m); // scan at t=0
        let after_first = m.total_pages_migrated;
        m.step(); // t=1ms
        an.step(&mut m); // within the period: no work
        assert_eq!(m.total_pages_migrated, after_first);
    }
}
