//! Static Tuning baseline — manual CPU-affinity optimization
//! (Blagodurov-style, the paper's second Fig-7 comparator).
//!
//! An administrator pins each application to a node with `taskset` +
//! `numactl --membind`. We model the *competent* admin: workloads are
//! round-robined across nodes so each socket hosts a similar thread
//! count, and pinned memory is bound (migrated) to the pinned node. The
//! paper's observation — this wins for coarse low-sharing apps like
//! blackscholes/bodytrack/fluidanimate but is inconsistent elsewhere
//! and "not practical" — emerges from the pins being static while load
//! and phases move.

use crate::config::StaticPin;
use crate::sim::Machine;

/// Apply explicit admin pins (comm -> node) to all matching processes.
///
/// `bind_memory = false` models the paper's Static Tuning baseline: the
/// CPU-affinity technique (taskset) that "statically fixes tasks into a
/// specific NUMA node" and thereby "damages the effective memory
/// utilization" — pages stay where first-touch left them. `true` models
/// the diligent `numactl --membind` admin (used for explicit config pins
/// and the round-robin helper).
pub fn apply_pins(machine: &mut Machine, pins: &[StaticPin], bind_memory: bool) {
    let pids = machine.running_pids();
    for pid in pids {
        let Some(p) = machine.process(pid) else { continue };
        let Some(pin) = pins.iter().find(|pin| pin.process == p.comm) else {
            continue;
        };
        let node = pin.node;
        let rss = p.pages.total();
        machine.pin_process(pid, node);
        if bind_memory {
            machine.migrate_pages(pid, node, rss);
        }
    }
}

/// The "competent admin" assignment: walk processes in pid order and
/// round-robin them across nodes, pinning threads and memory together.
/// Returns the generated pin list (for logging).
pub fn round_robin_pins(machine: &mut Machine) -> Vec<StaticPin> {
    let nodes = machine.topo.nodes;
    let mut out = Vec::new();
    let pids = machine.running_pids();
    for (i, pid) in pids.into_iter().enumerate() {
        let node = i % nodes;
        let Some(p) = machine.process(pid) else { continue };
        let comm = p.comm.clone();
        let rss = p.pages.total();
        machine.pin_process(pid, node);
        machine.migrate_pages(pid, node, rss);
        out.push(StaticPin { process: comm, node });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Placement, TaskBehavior};
    use crate::topology::NumaTopology;

    fn machine() -> Machine {
        Machine::new(NumaTopology::r910_40core(), 9)
    }

    #[test]
    fn apply_pins_moves_threads_and_memory() {
        let mut m = machine();
        let pid = m.spawn("mysqld", TaskBehavior::mem_bound(1e9), 1.0, 4, Placement::Node(0));
        apply_pins(
            &mut m,
            &[StaticPin { process: "mysqld".into(), node: 2 }],
            true,
        );
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2);
        assert_eq!(p.pinned_node, Some(2));
        let fr = p.pages.fractions();
        assert!(fr[2] > 0.99, "memory should be bound: {fr:?}");
    }

    #[test]
    fn cpu_only_pins_leave_memory_behind() {
        let mut m = machine();
        let pid = m.spawn("mysqld", TaskBehavior::mem_bound(1e9), 1.0, 4, Placement::Node(0));
        apply_pins(
            &mut m,
            &[StaticPin { process: "mysqld".into(), node: 2 }],
            false,
        );
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2);
        // The paper's complaint about CPU-affinity tuning: the task moved
        // but its memory did not.
        let fr = p.pages.fractions();
        assert!(fr[0] > 0.99, "pages stranded at first touch: {fr:?}");
    }

    #[test]
    fn apply_pins_ignores_unmatched_comms() {
        let mut m = machine();
        let pid = m.spawn("other", TaskBehavior::cpu_bound(1e9), 1.0, 2, Placement::Node(1));
        apply_pins(&mut m, &[StaticPin { process: "mysqld".into(), node: 2 }], true);
        let p = m.process(pid).unwrap();
        assert_eq!(p.pinned_node, None);
        assert_eq!(p.home_node(4, 10), 1);
    }

    #[test]
    fn round_robin_spreads_processes() {
        let mut m = machine();
        for i in 0..8 {
            m.spawn(&format!("w{i}"), TaskBehavior::cpu_bound(1e9), 1.0, 2, Placement::LeastLoaded);
        }
        let pins = round_robin_pins(&mut m);
        assert_eq!(pins.len(), 8);
        // Two processes per node on the 4-node box.
        for node in 0..4 {
            assert_eq!(pins.iter().filter(|p| p.node == node).count(), 2);
        }
        // Every process actually pinned.
        for p in m.processes() {
            assert!(p.pinned_node.is_some());
        }
    }
}
