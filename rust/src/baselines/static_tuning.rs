//! Static Tuning baseline — manual CPU-affinity optimization
//! (Blagodurov-style, the paper's second Fig-7 comparator).
//!
//! An administrator pins each application to a node with `taskset` +
//! `numactl --membind`. We model the *competent* admin: workloads are
//! round-robined across nodes so each socket hosts a similar thread
//! count, and pinned memory is bound (migrated) to the pinned node. The
//! paper's observation — this wins for coarse low-sharing apps like
//! blackscholes/bodytrack/fluidanimate but is inconsistent elsewhere
//! and "not practical" — emerges from the pins being static while load
//! and phases move.

use crate::config::StaticPin;
use crate::scheduler::PlacementLedger;
use crate::sim::Machine;

/// Apply explicit admin pins (comm -> node) to all matching processes,
/// recording each one in the shared placement ledger — an admin pin
/// occupies powerful-core slots exactly like a scheduler placement, so
/// every policy reasons from the same occupancy view.
///
/// `bind_memory = false` models the paper's Static Tuning baseline: the
/// CPU-affinity technique (taskset) that "statically fixes tasks into a
/// specific NUMA node" and thereby "damages the effective memory
/// utilization" — pages stay where first-touch left them. `true` models
/// the diligent `numactl --membind` admin (used for explicit config pins
/// and the round-robin helper).
pub fn apply_pins(
    machine: &mut Machine,
    pins: &[StaticPin],
    bind_memory: bool,
    ledger: &mut PlacementLedger,
) {
    let pids = machine.running_pids();
    for pid in pids {
        let Some(p) = machine.process(pid) else { continue };
        let Some(pin) = pins.iter().find(|pin| pin.process == p.comm) else {
            continue;
        };
        let node = pin.node;
        let rss = p.pages.total();
        let threads = p.nthreads() as i64;
        machine.pin_process(pid, node);
        ledger.record_placement(pid, node, threads, true);
        if bind_memory {
            machine.migrate_pages(pid, node, rss);
        }
    }
}

/// The "competent admin" assignment: walk processes in pid order and
/// fill each one onto the node the shared ledger shows least occupied
/// (capacity-aware round-robin; ties break toward the lowest node id,
/// so equal-thread workloads spread exactly like the old index modulo).
/// Threads and memory pin together. Returns the generated pin list.
pub fn round_robin_pins(machine: &mut Machine, ledger: &mut PlacementLedger) -> Vec<StaticPin> {
    let nodes = machine.topo.nodes;
    let mut out = Vec::new();
    let pids = machine.running_pids();
    for pid in pids {
        let node = (0..nodes)
            .min_by_key(|&n| (ledger.occupied(n), n))
            .expect("topology has nodes");
        let Some(p) = machine.process(pid) else { continue };
        let comm = p.comm.clone();
        let rss = p.pages.total();
        let threads = p.nthreads() as i64;
        machine.pin_process(pid, node);
        machine.migrate_pages(pid, node, rss);
        ledger.record_placement(pid, node, threads, true);
        out.push(StaticPin { process: comm, node });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Placement, TaskBehavior};
    use crate::topology::NumaTopology;

    fn machine() -> Machine {
        Machine::new(NumaTopology::r910_40core(), 9)
    }

    fn ledger(m: &Machine) -> PlacementLedger {
        PlacementLedger::from_topology(&m.topo)
    }

    #[test]
    fn apply_pins_moves_threads_and_memory() {
        let mut m = machine();
        let pid = m.spawn("mysqld", TaskBehavior::mem_bound(1e9), 1.0, 4, Placement::Node(0));
        let mut l = ledger(&m);
        apply_pins(
            &mut m,
            &[StaticPin { process: "mysqld".into(), node: 2 }],
            true,
            &mut l,
        );
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2);
        assert_eq!(p.pinned_node, Some(2));
        let fr = p.pages.fractions();
        assert!(fr[2] > 0.99, "memory should be bound: {fr:?}");
        // The pin occupies powerful-core slots in the shared view.
        assert_eq!(l.occupied(2), 4);
        assert_eq!(l.placement(pid).map(|pl| pl.pinned), Some(true));
        l.check_invariants(&[pid].into_iter().collect()).unwrap();
    }

    #[test]
    fn cpu_only_pins_leave_memory_behind() {
        let mut m = machine();
        let pid = m.spawn("mysqld", TaskBehavior::mem_bound(1e9), 1.0, 4, Placement::Node(0));
        let mut l = ledger(&m);
        apply_pins(
            &mut m,
            &[StaticPin { process: "mysqld".into(), node: 2 }],
            false,
            &mut l,
        );
        let p = m.process(pid).unwrap();
        assert_eq!(p.home_node(4, 10), 2);
        // The paper's complaint about CPU-affinity tuning: the task moved
        // but its memory did not.
        let fr = p.pages.fractions();
        assert!(fr[0] > 0.99, "pages stranded at first touch: {fr:?}");
    }

    #[test]
    fn apply_pins_ignores_unmatched_comms() {
        let mut m = machine();
        let pid = m.spawn("other", TaskBehavior::cpu_bound(1e9), 1.0, 2, Placement::Node(1));
        let mut l = ledger(&m);
        apply_pins(&mut m, &[StaticPin { process: "mysqld".into(), node: 2 }], true, &mut l);
        let p = m.process(pid).unwrap();
        assert_eq!(p.pinned_node, None);
        assert_eq!(p.home_node(4, 10), 1);
        assert_eq!(l.placed_count(), 0);
    }

    #[test]
    fn round_robin_spreads_processes() {
        let mut m = machine();
        for i in 0..8 {
            m.spawn(&format!("w{i}"), TaskBehavior::cpu_bound(1e9), 1.0, 2, Placement::LeastLoaded);
        }
        let mut l = ledger(&m);
        let pins = round_robin_pins(&mut m, &mut l);
        assert_eq!(pins.len(), 8);
        // Two processes per node on the 4-node box.
        for node in 0..4 {
            assert_eq!(pins.iter().filter(|p| p.node == node).count(), 2);
            assert_eq!(l.occupied(node), 4, "ledger mirrors the spread");
        }
        // Every process actually pinned.
        for p in m.processes() {
            assert!(p.pinned_node.is_some());
        }
    }

    #[test]
    fn round_robin_balances_uneven_thread_counts() {
        // A fat 8-thread service plus three 2-thread workers: the
        // ledger-driven admin packs the workers onto the emptier nodes
        // instead of blindly cycling by index past the fat pin.
        let mut m = machine();
        m.spawn("fat", TaskBehavior::cpu_bound(1e9), 1.0, 8, Placement::LeastLoaded);
        for i in 0..3 {
            m.spawn(&format!("w{i}"), TaskBehavior::cpu_bound(1e9), 1.0, 2, Placement::LeastLoaded);
        }
        let mut l = ledger(&m);
        let pins = round_robin_pins(&mut m, &mut l);
        assert_eq!(pins[0].node, 0, "fat lands first on node 0");
        assert_eq!(l.occupied(0), 8);
        for node in 1..4 {
            assert_eq!(l.occupied(node), 2, "workers avoid the fat node");
        }
        l.check_invariants(&m.processes().map(|p| p.pid).collect()).unwrap();
    }
}
