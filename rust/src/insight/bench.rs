//! Perf-regression detection over the append-only bench history.
//!
//! `BENCH_HISTORY.jsonl` (schema `numasched-bench-history/v1`) holds one
//! line per measured `bench-suite` run: an id (CI commit sha or
//! `local`), the smoke marker, and every numeric leaf of that run's
//! `BENCH_PERF.json`, flattened to `section.name`. The CI bench job
//! appends to it — *measured* runs only, never the provisional
//! placeholder — and `insight bench` reads it back:
//!
//! * baseline = the lower median of all prior comparable entries
//!   (same smoke mode), so one fast outlier cannot ratchet the bar up;
//! * each metric is classed into a family — [`Family::Time`] (lower is
//!   better), [`Family::Rate`] (higher is better), [`Family::Info`]
//!   (shape/config values, never gated) — with per-family noise
//!   thresholds ([`Noise`], CLI-overridable);
//! * the gate only arms once ≥ 3 comparable entries exist — below
//!   that, bare-metal CI runner variance would make verdicts noise.

use crate::telemetry::provenance::esc;
use crate::telemetry::registry::json_str;

use super::load::{json_bool, BenchDoc};
use super::{LoadError, INSIGHT_SCHEMA};

/// Schema tag of one `BENCH_HISTORY.jsonl` line.
pub const HISTORY_SCHEMA: &str = "numasched-bench-history/v1";

/// Minimum comparable history entries before the gate arms.
pub const GATE_MIN_ENTRIES: usize = 3;

/// One appended bench run.
#[derive(Clone, Debug, PartialEq)]
pub struct HistoryEntry {
    pub id: String,
    pub smoke: bool,
    pub metrics: Vec<(String, f64)>,
}

/// Parse the whole history file. Every line must carry the schema tag;
/// a mangled line is a typed error with its line number.
pub fn parse_history(text: &str) -> Result<Vec<HistoryEntry>, LoadError> {
    const SURFACE: &str = "bench history";
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let bad = |detail| LoadError { surface: SURFACE, line: lineno, detail };
        if line.trim().is_empty() {
            continue;
        }
        if !line.contains(HISTORY_SCHEMA) {
            return Err(bad("missing history schema tag"));
        }
        let id = json_str(line, "id").ok_or_else(|| bad("missing id"))?.to_string();
        let smoke = json_bool(line, "smoke").ok_or_else(|| bad("missing smoke marker"))?;
        let pat = "\"metrics\":{";
        let start = line.find(pat).ok_or_else(|| bad("missing metrics object"))? + pat.len();
        let end = line[start..].find('}').ok_or_else(|| bad("unterminated metrics object"))?;
        let mut metrics = Vec::new();
        for pair in line[start..start + end].split(',') {
            if pair.trim().is_empty() {
                continue;
            }
            let (k, v) = pair.split_once(':').ok_or_else(|| bad("bad metric pair"))?;
            let name = k.trim().trim_matches('"');
            let value: f64 = v.trim().parse().map_err(|_| bad("bad metric value"))?;
            metrics.push((name.to_string(), value));
        }
        out.push(HistoryEntry { id, smoke, metrics });
    }
    Ok(out)
}

/// Render one history line from a parsed (measured) bench snapshot.
/// The caller is responsible for refusing provisional snapshots.
pub fn render_history_entry(id: &str, doc: &BenchDoc) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"schema\":\"{HISTORY_SCHEMA}\",\"id\":\"{}\",\"smoke\":{},\"metrics\":{{",
        esc(id),
        doc.smoke
    ));
    for (i, (name, value)) in doc.metrics.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{value}", esc(name)));
    }
    out.push_str("}}");
    out.push('\n');
    out
}

/// Metric family — decides direction and whether a metric can gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Lower is better (latencies, per-op costs, alloc counts).
    Time,
    /// Higher is better (throughputs, speedups, cache hits).
    Rate,
    /// Configuration/shape values (iteration counts, node counts):
    /// reported, never gated.
    Info,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Time => "time",
            Family::Rate => "rate",
            Family::Info => "info",
        }
    }
}

/// Classify a flattened metric name. Order matters: rate markers win
/// (`task_ticks_per_s` is a rate despite containing `ticks`), then
/// shape counts, then anything time/alloc-flavored.
pub fn family_of(name: &str) -> Family {
    let leaf = name.rsplit('.').next().unwrap_or(name);
    if leaf.ends_with("_per_s") || leaf.contains("speedup") || leaf.ends_with("_hits") {
        return Family::Rate;
    }
    const SHAPE: [&str; 9] =
        ["iters", "ticks", "cells", "threads", "workers", "pids", "nodes", "renders", "ops"];
    for s in SHAPE {
        if leaf == s || leaf.ends_with(&format!("_{s}")) {
            return Family::Info;
        }
    }
    if leaf.contains("ns") || leaf.contains("ms") || leaf.contains("allocs") {
        return Family::Time;
    }
    Family::Info
}

/// Per-family noise thresholds. A time metric regresses when it exceeds
/// `baseline * time_factor`; a rate metric when it drops below
/// `baseline * rate_factor`. Defaults are deliberately loose — CI
/// runners are shared hardware.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Noise {
    pub time_factor: f64,
    pub rate_factor: f64,
}

impl Default for Noise {
    fn default() -> Self {
        Noise { time_factor: 1.35, rate_factor: 0.75 }
    }
}

/// Parse a `--noise time=1.5,rate=0.8` override (either key optional).
pub fn parse_noise(spec: &str) -> Result<Noise, LoadError> {
    const SURFACE: &str = "noise spec";
    let bad = |detail| LoadError { surface: SURFACE, line: 0, detail };
    let mut n = Noise::default();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, value) = part.split_once('=').ok_or_else(|| bad("expected key=factor"))?;
        let factor: f64 = value.trim().parse().map_err(|_| bad("factor is not a number"))?;
        if factor.is_nan() || factor <= 0.0 {
            return Err(bad("factor must be positive"));
        }
        match key.trim() {
            "time" => n.time_factor = factor,
            "rate" => n.rate_factor = factor,
            _ => return Err(bad("unknown family (want time= or rate=)")),
        }
    }
    Ok(n)
}

/// One metric's trend verdict.
#[derive(Clone, Debug, PartialEq)]
pub struct TrendRow {
    pub metric: String,
    pub family: Family,
    pub baseline: f64,
    pub latest: f64,
    /// `latest / baseline` (0 when the baseline is 0).
    pub ratio: f64,
    /// `"ok"`, `"regression"`, `"info"`, or `"new"` (no prior sample).
    pub verdict: &'static str,
}

/// The full bench analysis.
#[derive(Debug, Default)]
pub struct BenchAnalysis {
    /// Total history entries read.
    pub entries: usize,
    /// Entries comparable with the latest (same smoke mode), inclusive.
    pub comparable: usize,
    /// Whether `--gate` may fail the build.
    pub gate_armed: bool,
    pub rows: Vec<TrendRow>,
    pub regressions: usize,
    pub note: String,
}

/// Lower median: for an even count, the lower of the two middle values
/// — the conservative baseline choice (never inflated by one fast run).
fn lower_median(mut values: Vec<f64>) -> f64 {
    values.sort_by(|a, b| a.total_cmp(b));
    values[(values.len() - 1) / 2]
}

/// Analyze the history's latest entry against its prior baseline.
pub fn analyze(entries: &[HistoryEntry], noise: &Noise) -> BenchAnalysis {
    let Some(latest) = entries.last() else {
        return BenchAnalysis {
            note: "history is empty — nothing to analyze".to_string(),
            ..BenchAnalysis::default()
        };
    };
    let prior: Vec<&HistoryEntry> = entries[..entries.len() - 1]
        .iter()
        .filter(|e| e.smoke == latest.smoke)
        .collect();
    let comparable = prior.len() + 1;
    let gate_armed = comparable >= GATE_MIN_ENTRIES;
    let mut rows = Vec::new();
    let mut regressions = 0;
    for (name, latest_value) in &latest.metrics {
        let family = family_of(name);
        let prior_values: Vec<f64> = prior
            .iter()
            .flat_map(|e| e.metrics.iter().filter(|(n, _)| n == name).map(|(_, v)| *v))
            .collect();
        let (baseline, verdict) = if prior_values.is_empty() {
            (*latest_value, "new")
        } else {
            let base = lower_median(prior_values);
            let verdict = match family {
                Family::Info => "info",
                Family::Time => {
                    let bar = if base > 0.0 { base * noise.time_factor } else { 0.0 };
                    if *latest_value > bar {
                        "regression"
                    } else {
                        "ok"
                    }
                }
                Family::Rate => {
                    if base > 0.0 && *latest_value < base * noise.rate_factor {
                        "regression"
                    } else if base > 0.0 {
                        "ok"
                    } else {
                        "info"
                    }
                }
            };
            (base, verdict)
        };
        if verdict == "regression" {
            regressions += 1;
        }
        let ratio = if baseline != 0.0 { latest_value / baseline } else { 0.0 };
        rows.push(TrendRow {
            metric: name.clone(),
            family,
            baseline,
            latest: *latest_value,
            ratio,
            verdict,
        });
    }
    let note = format!(
        "{} entries, {} comparable (latest id={} smoke={}); gate {}",
        entries.len(),
        comparable,
        latest.id,
        latest.smoke,
        if gate_armed {
            "armed"
        } else {
            "disarmed (needs >= 3 comparable entries)"
        }
    );
    BenchAnalysis {
        entries: entries.len(),
        comparable,
        gate_armed,
        rows,
        regressions,
        note,
    }
}

impl BenchAnalysis {
    /// Text table, one row per metric.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("insight bench: {}\n", self.note));
        if self.rows.is_empty() {
            return out;
        }
        out.push_str(
            "metric                          family  baseline      latest        ratio   verdict\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{:<30}  {:<6}  {:<12}  {:<12}  {:<6}  {}\n",
                r.metric,
                r.family.name(),
                r.baseline,
                r.latest,
                format!("{:.3}", r.ratio),
                r.verdict
            ));
        }
        out.push_str(&format!("regressions: {}\n", self.regressions));
        out
    }

    /// `numasched-insight/v1` JSON report.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\":\"{INSIGHT_SCHEMA}\",\"verb\":\"bench\",\"entries\":{},\
             \"comparable\":{},\"gate_armed\":{},\"regressions\":{},\"note\":\"{}\",\"rows\":[",
            self.entries,
            self.comparable,
            self.gate_armed,
            self.regressions,
            esc(&self.note)
        ));
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"metric\":\"{}\",\"family\":\"{}\",\"baseline\":{},\"latest\":{},\
                 \"ratio\":{:.3},\"verdict\":\"{}\"}}",
                esc(&r.metric),
                r.family.name(),
                r.baseline,
                r.latest,
                r.ratio,
                r.verdict
            ));
        }
        out.push_str("]}");
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: &str, ns_p50: f64, ticks_per_s: f64) -> HistoryEntry {
        HistoryEntry {
            id: id.to_string(),
            smoke: true,
            metrics: vec![
                ("roundtrip.ns_p50".to_string(), ns_p50),
                ("sim.task_ticks_per_s".to_string(), ticks_per_s),
                ("roundtrip.iters".to_string(), 2000.0),
            ],
        }
    }

    #[test]
    fn family_classification_covers_the_bench_leaves() {
        assert_eq!(family_of("roundtrip.ns_p50"), Family::Time);
        assert_eq!(family_of("roundtrip.allocs_per_sample"), Family::Time);
        assert_eq!(family_of("scale.ns_per_tick"), Family::Time);
        assert_eq!(family_of("metrics.hot_ns_per_op"), Family::Time);
        assert_eq!(family_of("scale.monitor_full_ms"), Family::Time);
        assert_eq!(family_of("sim.task_ticks_per_s"), Family::Rate);
        assert_eq!(family_of("sweep.speedup"), Family::Rate);
        assert_eq!(family_of("scale.monitor_incr_speedup"), Family::Rate);
        assert_eq!(family_of("scale.monitor_incr_hits"), Family::Rate);
        assert_eq!(family_of("roundtrip.iters"), Family::Info);
        assert_eq!(family_of("sim.ticks"), Family::Info);
        assert_eq!(family_of("metrics.hot_ops"), Family::Info);
        assert_eq!(family_of("metrics.epoch_renders"), Family::Info);
        assert_eq!(family_of("scale.sweep_workers"), Family::Info);
    }

    #[test]
    fn history_roundtrips_through_render_and_parse() {
        let doc = BenchDoc {
            smoke: true,
            provisional: false,
            metrics: vec![
                ("roundtrip.ns_p50".to_string(), 9000.0),
                ("sweep.speedup".to_string(), 3.25),
            ],
        };
        let line = render_history_entry("abc123", &doc);
        assert!(line.starts_with("{\"schema\":\"numasched-bench-history/v1\",\"id\":\"abc123\""));
        let parsed = parse_history(&line).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].id, "abc123");
        assert!(parsed[0].smoke);
        assert_eq!(parsed[0].metrics[0], ("roundtrip.ns_p50".to_string(), 9000.0));
        assert_eq!(parsed[0].metrics[1], ("sweep.speedup".to_string(), 3.25));
    }

    #[test]
    fn mangled_history_lines_yield_typed_errors() {
        let doc = BenchDoc {
            smoke: false,
            provisional: false,
            metrics: vec![("x.y".to_string(), 1.0)],
        };
        let good = render_history_entry("a", &doc);
        let text = format!("{good}{{\"schema\":\"numasched-bench-history/v1\",\"id\":\"b\"}}\n");
        let err = parse_history(&text).unwrap_err();
        assert_eq!(err.line, 2);
        assert_eq!(err.detail, "missing smoke marker");
        assert_eq!(parse_history("junk\n").unwrap_err().detail, "missing history schema tag");
    }

    #[test]
    fn gate_stays_disarmed_below_three_comparable_entries() {
        let noise = Noise::default();
        let a = analyze(&[entry("a", 9000.0, 4e6)], &noise);
        assert!(!a.gate_armed);
        assert_eq!(a.comparable, 1);
        assert!(a.rows.iter().all(|r| r.verdict == "new"));

        let two = [entry("a", 9000.0, 4e6), entry("b", 9100.0, 3.9e6)];
        assert!(!analyze(&two, &noise).gate_armed);

        // A smoke=false entry in the middle is not comparable.
        let mut mixed = two.to_vec();
        mixed.insert(1, HistoryEntry { id: "full".to_string(), smoke: false, metrics: vec![] });
        let a = analyze(&mixed, &noise);
        assert_eq!(a.entries, 3);
        assert_eq!(a.comparable, 2);
        assert!(!a.gate_armed);
    }

    #[test]
    fn time_regressions_and_rate_regressions_are_detected() {
        let noise = Noise::default();
        let stable = [
            entry("a", 9000.0, 4e6),
            entry("b", 9100.0, 4.1e6),
            entry("c", 8900.0, 3.9e6),
            entry("d", 9050.0, 4.0e6),
        ];
        let a = analyze(&stable, &noise);
        assert!(a.gate_armed);
        assert_eq!(a.regressions, 0);
        let p50 = a.rows.iter().find(|r| r.metric == "roundtrip.ns_p50").unwrap();
        assert_eq!(p50.verdict, "ok");
        assert_eq!(p50.baseline, 9000.0, "lower median of {{9000, 9100, 8900}}");

        // Latency blows past baseline * 1.35.
        let mut slow = stable.to_vec();
        slow.push(entry("e", 20000.0, 4.0e6));
        let a = analyze(&slow, &noise);
        assert_eq!(a.regressions, 1);
        assert_eq!(
            a.rows.iter().find(|r| r.metric == "roundtrip.ns_p50").unwrap().verdict,
            "regression"
        );

        // Throughput collapses below baseline * 0.75.
        let mut choked = stable.to_vec();
        choked.push(entry("f", 9000.0, 1e6));
        let a = analyze(&choked, &noise);
        assert_eq!(a.regressions, 1);
        let row = a.rows.iter().find(|r| r.metric == "sim.task_ticks_per_s").unwrap();
        assert_eq!(row.verdict, "regression");
        // Info metrics never regress, whatever they do.
        assert!(a.rows.iter().filter(|r| r.family == Family::Info).all(|r| r.verdict == "info"));
        // Reports render byte-identically.
        let b = analyze(&choked, &noise);
        assert_eq!(a.render_text(), b.render_text());
        assert_eq!(a.to_json(), b.to_json());
        assert!(a.to_json().contains("\"gate_armed\":true"));
    }

    #[test]
    fn zero_baseline_time_metric_regresses_on_any_growth() {
        let noise = Noise::default();
        let mk = |allocs: f64| HistoryEntry {
            id: "x".to_string(),
            smoke: true,
            metrics: vec![("roundtrip.allocs_per_sample".to_string(), allocs)],
        };
        let grew = [mk(0.0), mk(0.0), mk(0.0), mk(2.0)];
        let a = analyze(&grew, &noise);
        assert_eq!(a.regressions, 1, "0 -> 2 allocs is a regression, ratio games aside");
        let flat = [mk(0.0), mk(0.0), mk(0.0), mk(0.0)];
        assert_eq!(analyze(&flat, &noise).regressions, 0);
    }

    #[test]
    fn noise_spec_parses_and_rejects() {
        assert_eq!(parse_noise("").unwrap(), Noise::default());
        let n = parse_noise("time=1.5,rate=0.9").unwrap();
        assert_eq!(n.time_factor, 1.5);
        assert_eq!(n.rate_factor, 0.9);
        assert_eq!(parse_noise("rate=0.5").unwrap().time_factor, Noise::default().time_factor);
        assert!(parse_noise("time=fast").is_err());
        assert!(parse_noise("space=1.5").is_err());
        assert!(parse_noise("time=-1").is_err());
        assert!(parse_noise("time").is_err());
    }
}
