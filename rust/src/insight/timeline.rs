//! Per-pid causal timelines: one ordered lifecycle view stitched from
//! whatever artifact is at hand.
//!
//! A metrics stream knows *why* the scheduler acted (explain rows) and
//! *what the system suffered* (chaos fault and degradation counters); a
//! trace knows *what happened* (events, executed decisions, occupancy).
//! Either renders into the same entry list: time-ordered, each entry
//! tagged with the pid it concerns (or none for machine-wide
//! transitions), so `insight timeline <file> [pid]` answers "what is
//! the life story of pid 1004?" from any artifact.
//!
//! Ordering is deterministic: entries are collected in a fixed
//! per-section order and stably sorted by time, so equal timestamps
//! keep their collection order.

use crate::telemetry::provenance::esc;

use super::load::{FlightDoc, MetricsDoc, TraceDoc};
use super::INSIGHT_SCHEMA;

/// Counters whose epoch-over-epoch increments are lifecycle transitions
/// worth surfacing: chaos faults, graceful-degradation recoveries, and
/// stale/quarantine events. Machine-wide — the metrics registry does
/// not break these down per pid.
pub const TRANSITION_COUNTERS: [&str; 12] = [
    "chaos_reads_faulted",
    "chaos_pids_vanished",
    "chaos_migrations_faulted",
    "chaos_node_events",
    "monitor_read_retries",
    "monitor_stale_served",
    "monitor_quarantines",
    "skip_stale",
    "skip_offline",
    "move_faults",
    "migrate_faults",
    "evacuations",
];

/// One timeline entry. `pid == None` marks a machine-wide entry, kept
/// under any pid filter — a fault storm is part of every pid's story.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEntry {
    pub t: f64,
    pub pid: Option<i64>,
    pub kind: &'static str,
    pub detail: String,
}

/// A rendered lifecycle view over one artifact.
#[derive(Debug, Default)]
pub struct Timeline {
    /// Which artifact kind fed this timeline (`"metrics"`, `"trace"`,
    /// `"flight"`).
    pub source: &'static str,
    /// Run label (scenario/stream name).
    pub label: String,
    pub pid_filter: Option<i64>,
    pub entries: Vec<TimelineEntry>,
}

fn keep(pid_filter: Option<i64>, pid: Option<i64>) -> bool {
    match (pid_filter, pid) {
        (None, _) => true,
        (Some(_), None) => true,
        (Some(f), Some(p)) => f == p,
    }
}

fn finish(
    mut entries: Vec<TimelineEntry>,
    source: &'static str,
    label: &str,
    pid: Option<i64>,
) -> Timeline {
    entries.retain(|e| keep(pid, e.pid));
    entries.sort_by(|x, y| x.t.total_cmp(&y.t));
    Timeline { source, label: label.to_string(), pid_filter: pid, entries }
}

fn opt_u64(v: Option<u64>) -> String {
    match v {
        Some(n) => n.to_string(),
        None => "-".to_string(),
    }
}

/// Build a timeline from a parsed metrics stream: explain rows (the
/// scheduler's reasoning, per pid), transition-counter increments and
/// `procs_running` changes (machine-wide), and final per-proc outcomes.
pub fn from_metrics(doc: &MetricsDoc, pid: Option<i64>) -> Timeline {
    let mut entries = Vec::new();
    for r in &doc.explains {
        entries.push(TimelineEntry {
            t: r.t_ms as f64,
            pid: Some(r.pid),
            kind: "decision",
            detail: format!(
                "{} comm={} from={} chosen={} dist_best={} cands={}",
                r.outcome,
                r.comm,
                r.from,
                opt_u64(r.chosen),
                r.dist_best,
                r.candidates.len()
            ),
        });
    }
    let mut prev = [0u64; TRANSITION_COUNTERS.len()];
    let mut prev_running: Option<f64> = None;
    for e in &doc.epochs {
        for (name, last) in TRANSITION_COUNTERS.iter().zip(prev.iter_mut()) {
            let cur = e.counters.get(*name).copied().unwrap_or(0);
            if cur != *last {
                // saturating: counters are cumulative, but mangled
                // input must degrade, not panic.
                entries.push(TimelineEntry {
                    t: e.t_ms as f64,
                    pid: None,
                    kind: "transition",
                    detail: format!("{name} +{} (cum {cur})", cur.saturating_sub(*last)),
                });
                *last = cur;
            }
        }
        if let Some(cur) = e.gauges.get("procs_running").copied() {
            let changed = match prev_running {
                Some(p) => p.to_bits() != cur.to_bits(),
                None => true,
            };
            if changed {
                entries.push(TimelineEntry {
                    t: e.t_ms as f64,
                    pid: None,
                    kind: "population",
                    detail: format!("procs_running={cur}"),
                });
                prev_running = Some(cur);
            }
        }
    }
    let end = doc
        .end_ms
        .map(|m| m as f64)
        .or_else(|| doc.epochs.last().map(|e| e.t_ms as f64))
        .unwrap_or(0.0);
    for r in &doc.results {
        let runtime = match r.runtime_ms {
            Some(ms) => format!("{ms}"),
            None => "-".to_string(),
        };
        entries.push(TimelineEntry {
            t: end,
            pid: Some(r.pid),
            kind: "result",
            detail: format!(
                "comm={} runtime_ms={runtime} mean_speed={} degradation={} migrations={}",
                r.comm, r.mean_speed, r.degradation, r.migrations
            ),
        });
    }
    finish(entries, "metrics", &doc.name, pid)
}

/// Build a timeline from a parsed scenario trace: fired events fan out
/// per touched pid (or one machine-wide entry when none), executed
/// decisions attach to their pid, occupancy samples surface only when
/// the running count changes, and the summary closes the view.
pub fn from_trace(doc: &TraceDoc, pid: Option<i64>) -> Timeline {
    let mut entries = Vec::new();
    for e in &doc.events {
        let detail = format!(
            "{} comm={} node={} pages={}",
            e.kind,
            e.comm,
            opt_u64(e.node),
            opt_u64(e.pages)
        );
        if e.pids.is_empty() {
            entries.push(TimelineEntry { t: e.t, pid: None, kind: "event", detail });
        } else {
            for &p in &e.pids {
                entries.push(TimelineEntry {
                    t: e.t,
                    pid: Some(p),
                    kind: "event",
                    detail: detail.clone(),
                });
            }
        }
    }
    for d in &doc.decisions {
        entries.push(TimelineEntry {
            t: d.t,
            pid: Some(d.pid),
            kind: "decision",
            detail: format!(
                "{} comm={} from={} to={} sticky_pages={}",
                d.reason, d.comm, d.from, d.to, d.sticky_pages
            ),
        });
    }
    let mut prev_running: Option<u64> = None;
    for o in &doc.occupancy {
        if prev_running != Some(o.running) {
            let occ: Vec<String> = o.occ.iter().map(|x| x.to_string()).collect();
            entries.push(TimelineEntry {
                t: o.t,
                pid: None,
                kind: "population",
                detail: format!("running={} occ=[{}]", o.running, occ.join(",")),
            });
            prev_running = Some(o.running);
        }
    }
    if let Some(s) = &doc.summary {
        entries.push(TimelineEntry {
            t: s.end_ms,
            pid: None,
            kind: "summary",
            detail: format!(
                "procs={} finished={} migrations={} pages_migrated={} decisions={}",
                s.procs, s.finished, s.migrations, s.pages_migrated, s.decisions
            ),
        });
    }
    finish(entries, "trace", &doc.scenario, pid)
}

/// Build a timeline from a flight dump: the retained metrics tail, with
/// the eviction context noted in the label.
pub fn from_flight(doc: &FlightDoc, pid: Option<i64>) -> Timeline {
    let mut t = from_metrics(&doc.metrics, pid);
    t.source = "flight";
    t.label = format!("{} ({} frames kept, {} evicted)", doc.reason, doc.frames, doc.evicted);
    t
}

impl Timeline {
    /// Fixed-width text view.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("insight timeline ({}): {}", self.source, self.label));
        if let Some(p) = self.pid_filter {
            out.push_str(&format!(", pid {p}"));
        }
        out.push('\n');
        out.push_str(&format!("{} entries\n", self.entries.len()));
        out.push_str("t_ms       pid     kind        detail\n");
        for e in &self.entries {
            let pid = match e.pid {
                Some(p) => p.to_string(),
                None => "-".to_string(),
            };
            out.push_str(&format!("{:<9}  {:<6}  {:<10}  {}\n", e.t, pid, e.kind, e.detail));
        }
        out
    }

    /// `numasched-insight/v1` JSON report.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\":\"{INSIGHT_SCHEMA}\",\"verb\":\"timeline\",\"source\":\"{}\",\
             \"label\":\"{}\",\"pid\":{},\"entries\":[",
            self.source,
            esc(&self.label),
            match self.pid_filter {
                Some(p) => p.to_string(),
                None => "null".to_string(),
            }
        ));
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"t\":{},\"pid\":{},\"kind\":\"{}\",\"detail\":\"{}\"}}",
                e.t,
                match e.pid {
                    Some(p) => p.to_string(),
                    None => "null".to_string(),
                },
                e.kind,
                esc(&e.detail)
            ));
        }
        out.push_str("]}");
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::load::{parse_metrics, parse_trace};
    use super::*;

    fn metrics_text() -> String {
        concat!(
            "{\"schema\":\"numasched-metrics/v1\",\"name\":\"s\",\"policy\":\"proposed\",\"seed\":1}\n",
            "{\"t\":100,\"explain\":\"moved\",\"pid\":7,\"comm\":\"web\",\"from\":0,\"chosen\":1,",
            "\"dist_best\":1,\"needed\":1.05,\"cooldown\":false,\"sticky\":0,\"cands\":[]}\n",
            "{\"t\":150,\"epoch\":0,\"c\":{\"evacuations\":0},\"g\":{\"procs_running\":2},\"h\":{}}\n",
            "{\"t\":300,\"epoch\":1,\"c\":{\"evacuations\":2},\"g\":{\"procs_running\":1},\"h\":{}}\n",
            "{\"result\":\"proc\",\"pid\":7,\"comm\":\"web\",\"runtime_ms\":900,\"mean_speed\":0.9,",
            "\"degradation\":1.2,\"migrations\":1}\n",
            "{\"end_ms\":1000,\"epochs\":2,\"explains\":1}\n",
        )
        .to_string()
    }

    #[test]
    fn metrics_timeline_stitches_decisions_transitions_and_results() {
        let doc = parse_metrics(&metrics_text()).unwrap();
        let t = from_metrics(&doc, None);
        let kinds: Vec<&str> = t.entries.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec!["decision", "population", "transition", "population", "result"]);
        assert!(t.entries[2].detail.contains("evacuations +2 (cum 2)"));
        assert_eq!(t.entries[4].t, 1000.0, "results anchor at end_ms");
        let text = t.render_text();
        assert!(text.starts_with("insight timeline (metrics): s\n"));
        assert!(text.contains("5 entries"));
        assert!(t.to_json().contains("\"verb\":\"timeline\""));
    }

    #[test]
    fn pid_filter_keeps_global_entries() {
        let doc = parse_metrics(&metrics_text()).unwrap();
        let t = from_metrics(&doc, Some(99));
        let kinds: Vec<&str> = t.entries.iter().map(|e| e.kind).collect();
        // pid-7 decision and result are filtered out; machine-wide
        // transitions and population changes stay.
        assert_eq!(kinds, vec!["population", "transition", "population"]);
        assert!(t.render_text().contains(", pid 99"));
    }

    #[test]
    fn trace_timeline_fans_events_out_per_pid() {
        let text = concat!(
            "{\"schema\":\"numasched-trace/v1\",\"scenario\":\"s\",\"preset\":\"p\",",
            "\"policy\":\"proposed\",\"seed\":1,\"horizon_ms\":1000,\"events\":1}\n",
            "{\"t\":100,\"ev\":\"daemon_burst\",\"comm\":\"burst\",\"pids\":[10,11]}\n",
            "{\"t\":200,\"decision\":\"speedup\",\"pid\":10,\"comm\":\"burst-0\",\"from\":0,\"to\":1,\"sticky_pages\":4}\n",
            "{\"t\":250,\"occ\":[5,5],\"rho\":[0.1,0.2],\"running\":2}\n",
            "{\"t\":500,\"occ\":[5,5],\"rho\":[0.1,0.2],\"running\":2}\n",
            "{\"end_ms\":1000,\"procs\":2,\"finished\":2,\"migrations\":1,\"pages_migrated\":4,\"decisions\":1}\n",
        );
        let doc = parse_trace(text).unwrap();
        let all = from_trace(&doc, None);
        let kinds: Vec<&str> = all.entries.iter().map(|e| e.kind).collect();
        // Two per-pid event entries, one decision, ONE population entry
        // (the second occupancy sample repeats running=2), the summary.
        assert_eq!(kinds, vec!["event", "event", "decision", "population", "summary"]);

        let one = from_trace(&doc, Some(11));
        let kinds: Vec<&str> = one.entries.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec!["event", "population", "summary"]);
    }

    #[test]
    fn renders_are_byte_identical_across_invocations() {
        let doc = parse_metrics(&metrics_text()).unwrap();
        let a = from_metrics(&doc, None);
        let b = from_metrics(&doc, None);
        assert_eq!(a.render_text(), b.render_text());
        assert_eq!(a.to_json(), b.to_json());
    }
}
