//! Insight engine: cross-run analytics over the artifacts the rest of
//! the system emits.
//!
//! Every other subsystem *writes* machine-readable surfaces — scenario
//! traces (`numasched-trace/v1`), metrics sidecars
//! (`numasched-metrics/v1`), flight-recorder dumps
//! (`numasched-flight/v1`), the bench snapshot
//! (`numasched-bench-perf/v1`) — and until this module nothing read
//! them back. The insight engine closes the loop:
//!
//! * [`load`] — typed loaders for all of the above plus the append-only
//!   bench history (`numasched-bench-history/v1`). Mangled input yields
//!   a [`LoadError`] with a line number, never a panic — the same
//!   discipline as `procfs::ParseError`.
//! * [`diff`] — a cross-run differ: aligns two runs of the same
//!   scenario epoch by epoch and reports ranked per-counter /
//!   per-histogram divergences, the first decision split (both
//!   candidate tables), and per-process degradation deltas.
//! * [`timeline`] — per-pid causal timelines stitching decisions,
//!   occupancy, stale/quarantine transitions, and chaos fault counters
//!   into one ordered lifecycle view.
//! * [`bench`] — a perf-regression detector over the bench history with
//!   per-metric-family noise thresholds and gate semantics for CI.
//!
//! Everything here is a pure function of its input bytes: reports
//! render byte-identically across repeated invocations (pinned by
//! `rust/tests/insight_engine.rs`), and the module never prints —
//! renderers return `String`s for the CLI layer to emit.

pub mod bench;
pub mod diff;
pub mod load;
pub mod timeline;

/// Schema tag stamped on every JSON report this module emits.
pub const INSIGHT_SCHEMA: &str = "numasched-insight/v1";

/// A typed artifact-loading failure: which surface, which line (1-based;
/// 0 when the failure is not tied to a line), and what was wrong. Like
/// `procfs::ParseError` this is the *only* way a loader rejects input —
/// mangled artifacts must never panic the analyzer reading them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadError {
    /// Artifact surface, e.g. `"metrics stream"` or `"bench history"`.
    pub surface: &'static str,
    /// 1-based line of the offending record (0 = whole-file problem).
    pub line: usize,
    /// What was malformed.
    pub detail: &'static str,
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "malformed {}: {}", self.surface, self.detail)
        } else {
            write!(f, "malformed {} (line {}): {}", self.surface, self.line, self.detail)
        }
    }
}

impl std::error::Error for LoadError {}
