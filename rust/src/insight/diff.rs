//! Cross-run differ: align two runs of the same scenario and explain
//! *where* and *why* they diverge.
//!
//! The golden-trace gate and the metrics determinism gate both answer
//! "are these byte-identical?" — useful as a tripwire, useless as a
//! diagnosis. This differ answers the follow-up: it aligns epochs,
//! finds the first counter/gauge/histogram divergence per metric
//! (ranked by how early and how large), the first decision split (both
//! candidate tables side by side — the actual root cause of almost
//! every trajectory fork), and per-process degradation deltas.
//!
//! Reports are pure functions of the two documents: rendering the same
//! pair twice yields byte-identical text and JSON.

use std::collections::{BTreeMap, BTreeSet};

use crate::telemetry::provenance::esc;
use crate::telemetry::registry::ParsedEpoch;

use super::load::{ExplainRecord, MetricsDoc, TraceDoc};
use super::INSIGHT_SCHEMA;

/// One header-level field mismatch (name, policy, seed, ...).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FieldDiff {
    pub field: &'static str,
    pub a: String,
    pub b: String,
}

/// First divergence of one counter, plus the final values on each side.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CounterDelta {
    pub name: String,
    /// Epoch number of the first sample where the sides disagree.
    pub first_epoch: u64,
    pub t_ms: u64,
    pub a_at: u64,
    pub b_at: u64,
    pub a_final: u64,
    pub b_final: u64,
}

/// First divergence of one gauge.
#[derive(Clone, Debug, PartialEq)]
pub struct GaugeDelta {
    pub name: String,
    pub first_epoch: u64,
    pub a_at: f64,
    pub b_at: f64,
}

/// First divergence of one histogram (count/sum/buckets compared as a
/// unit; the report carries the final count and sum per side).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistDelta {
    pub name: String,
    pub first_epoch: u64,
    pub a_n: u64,
    pub b_n: u64,
    pub a_sum: u64,
    pub b_sum: u64,
}

/// The first explain record where the two runs' decisions split. A
/// `None` side means that run had fewer explain rows (the streams fell
/// out of step before any row-level mismatch).
#[derive(Clone, Debug, PartialEq)]
pub struct ExplainSplit {
    /// 0-based index into the explain sequence.
    pub index: usize,
    pub a: Option<ExplainRecord>,
    pub b: Option<ExplainRecord>,
}

/// Per-process degradation-factor delta, keyed by (pid, comm). A `None`
/// side means the process only exists in the other run.
#[derive(Clone, Debug, PartialEq)]
pub struct ResultDelta {
    pub pid: i64,
    pub comm: String,
    pub a_degradation: Option<f64>,
    pub b_degradation: Option<f64>,
}

/// The full metrics-diff report.
#[derive(Debug, Default)]
pub struct MetricsDiff {
    pub a_label: String,
    pub b_label: String,
    pub policy_a: String,
    pub policy_b: String,
    pub header: Vec<FieldDiff>,
    pub epochs_a: usize,
    pub epochs_b: usize,
    pub explains_a: usize,
    pub explains_b: usize,
    pub counters: Vec<CounterDelta>,
    pub gauges: Vec<GaugeDelta>,
    pub hists: Vec<HistDelta>,
    pub explain_split: Option<ExplainSplit>,
    pub results: Vec<ResultDelta>,
}

fn counter_at(e: &ParsedEpoch, name: &str) -> u64 {
    e.counters.get(name).copied().unwrap_or(0)
}

fn diff_headers(a: &MetricsDoc, b: &MetricsDoc) -> Vec<FieldDiff> {
    let mut out = Vec::new();
    if a.name != b.name {
        out.push(FieldDiff { field: "name", a: a.name.clone(), b: b.name.clone() });
    }
    if a.policy != b.policy {
        out.push(FieldDiff { field: "policy", a: a.policy.clone(), b: b.policy.clone() });
    }
    if a.seed != b.seed {
        out.push(FieldDiff { field: "seed", a: a.seed.to_string(), b: b.seed.to_string() });
    }
    out
}

fn diff_counters(a: &[ParsedEpoch], b: &[ParsedEpoch]) -> Vec<CounterDelta> {
    let common = a.len().min(b.len());
    let mut names: BTreeSet<&str> = BTreeSet::new();
    if let Some(e) = a.last() {
        names.extend(e.counters.keys().map(|k| k.as_str()));
    }
    if let Some(e) = b.last() {
        names.extend(e.counters.keys().map(|k| k.as_str()));
    }
    let mut out = Vec::new();
    for name in names {
        let a_final = a.last().map(|e| counter_at(e, name)).unwrap_or(0);
        let b_final = b.last().map(|e| counter_at(e, name)).unwrap_or(0);
        let first = (0..common).find(|&i| counter_at(&a[i], name) != counter_at(&b[i], name));
        let (anchor, a_at, b_at) = match first {
            Some(i) => (&a[i], counter_at(&a[i], name), counter_at(&b[i], name)),
            None if a_final != b_final => {
                // Identical over the common prefix; the divergence is
                // the extra epochs of the longer run.
                let longer = if a.len() > b.len() { a } else { b };
                (&longer[common], a_final, b_final)
            }
            None => continue,
        };
        out.push(CounterDelta {
            name: name.to_string(),
            first_epoch: anchor.epoch,
            t_ms: anchor.t_ms,
            a_at,
            b_at,
            a_final,
            b_final,
        });
    }
    // Ranked: earliest divergence first, then by magnitude, then name.
    out.sort_by(|x, y| {
        x.first_epoch
            .cmp(&y.first_epoch)
            .then(y.a_final.abs_diff(y.b_final).cmp(&x.a_final.abs_diff(x.b_final)))
            .then(x.name.cmp(&y.name))
    });
    out
}

fn diff_gauges(a: &[ParsedEpoch], b: &[ParsedEpoch]) -> Vec<GaugeDelta> {
    let common = a.len().min(b.len());
    let mut names: BTreeSet<&str> = BTreeSet::new();
    if let Some(e) = a.last() {
        names.extend(e.gauges.keys().map(|k| k.as_str()));
    }
    if let Some(e) = b.last() {
        names.extend(e.gauges.keys().map(|k| k.as_str()));
    }
    let mut out = Vec::new();
    for name in names {
        let at = |e: &ParsedEpoch| e.gauges.get(name).copied().unwrap_or(0.0);
        let first = (0..common).find(|&i| at(&a[i]).to_bits() != at(&b[i]).to_bits());
        if let Some(i) = first {
            out.push(GaugeDelta {
                name: name.to_string(),
                first_epoch: a[i].epoch,
                a_at: at(&a[i]),
                b_at: at(&b[i]),
            });
        }
    }
    out
}

fn diff_hists(a: &[ParsedEpoch], b: &[ParsedEpoch]) -> Vec<HistDelta> {
    let common = a.len().min(b.len());
    let mut names: BTreeSet<&str> = BTreeSet::new();
    if let Some(e) = a.last() {
        names.extend(e.hists.keys().map(|k| k.as_str()));
    }
    if let Some(e) = b.last() {
        names.extend(e.hists.keys().map(|k| k.as_str()));
    }
    let mut out = Vec::new();
    for name in names {
        let first = (0..common).find(|&i| a[i].hists.get(name) != b[i].hists.get(name));
        if let Some(i) = first {
            let n_sum = |e: &ParsedEpoch| {
                e.hists.get(name).map(|h| (h.0, h.1)).unwrap_or((0, 0))
            };
            let (a_n, a_sum) = a.last().map(n_sum).unwrap_or((0, 0));
            let (b_n, b_sum) = b.last().map(n_sum).unwrap_or((0, 0));
            out.push(HistDelta {
                name: name.to_string(),
                first_epoch: a[i].epoch,
                a_n,
                b_n,
                a_sum,
                b_sum,
            });
        }
    }
    out
}

fn diff_explains(a: &[ExplainRecord], b: &[ExplainRecord]) -> Option<ExplainSplit> {
    let common = a.len().min(b.len());
    for i in 0..common {
        if a[i] != b[i] {
            return Some(ExplainSplit { index: i, a: Some(a[i].clone()), b: Some(b[i].clone()) });
        }
    }
    if a.len() != b.len() {
        return Some(ExplainSplit {
            index: common,
            a: a.get(common).cloned(),
            b: b.get(common).cloned(),
        });
    }
    None
}

fn diff_results(a: &MetricsDoc, b: &MetricsDoc) -> Vec<ResultDelta> {
    let key = |r: &super::load::ProcOutcome| (r.pid, r.comm.clone());
    let ma: BTreeMap<(i64, String), f64> =
        a.results.iter().map(|r| (key(r), r.degradation)).collect();
    let mb: BTreeMap<(i64, String), f64> =
        b.results.iter().map(|r| (key(r), r.degradation)).collect();
    let keys: BTreeSet<&(i64, String)> = ma.keys().chain(mb.keys()).collect();
    let mut out = Vec::new();
    for k in keys {
        let va = ma.get(k).copied();
        let vb = mb.get(k).copied();
        let same = match (va, vb) {
            (Some(x), Some(y)) => x.to_bits() == y.to_bits(),
            _ => false,
        };
        if !same {
            out.push(ResultDelta {
                pid: k.0,
                comm: k.1.clone(),
                a_degradation: va,
                b_degradation: vb,
            });
        }
    }
    out
}

/// Diff two parsed metrics streams.
pub fn diff_metrics(a_label: &str, a: &MetricsDoc, b_label: &str, b: &MetricsDoc) -> MetricsDiff {
    MetricsDiff {
        a_label: a_label.to_string(),
        b_label: b_label.to_string(),
        policy_a: a.policy.clone(),
        policy_b: b.policy.clone(),
        header: diff_headers(a, b),
        epochs_a: a.epochs.len(),
        epochs_b: b.epochs.len(),
        explains_a: a.explains.len(),
        explains_b: b.explains.len(),
        counters: diff_counters(&a.epochs, &b.epochs),
        gauges: diff_gauges(&a.epochs, &b.epochs),
        hists: diff_hists(&a.epochs, &b.epochs),
        explain_split: diff_explains(&a.explains, &b.explains),
        results: diff_results(a, b),
    }
}

fn opt_u64(v: Option<u64>) -> String {
    match v {
        Some(n) => n.to_string(),
        None => "-".to_string(),
    }
}

fn opt_f64(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "-".to_string(),
    }
}

/// Render one explain record with its full candidate table, indented
/// for embedding under a split header.
fn render_explain(side: &str, rec: &Option<ExplainRecord>, out: &mut String) {
    match rec {
        None => {
            out.push_str(&format!("  [{side}] <absent: this run emitted fewer explain rows>\n"));
        }
        Some(r) => {
            out.push_str(&format!(
                "  [{side}] t={} pid={} comm={} outcome={} from={} chosen={} dist_best={}\n",
                r.t_ms,
                r.pid,
                r.comm,
                r.outcome,
                r.from,
                opt_u64(r.chosen),
                r.dist_best
            ));
            out.push_str("      node  distance  score  ctrl_rho  route_rho  fits\n");
            for c in &r.candidates {
                out.push_str(&format!(
                    "      {:<4}  {:<8}  {:<5}  {:<8}  {:<9}  {}\n",
                    c.node,
                    c.distance,
                    c.score,
                    c.ctrl_rho,
                    c.route_rho,
                    if c.fits { "yes" } else { "no" }
                ));
            }
        }
    }
}

fn json_explain(rec: &Option<ExplainRecord>) -> String {
    match rec {
        None => "null".to_string(),
        Some(r) => {
            let mut cands = String::new();
            for (i, c) in r.candidates.iter().enumerate() {
                if i > 0 {
                    cands.push(',');
                }
                cands.push_str(&format!(
                    "{{\"n\":{},\"d\":{},\"s\":{},\"rho\":{},\"lrho\":{},\"fits\":{}}}",
                    c.node, c.distance, c.score, c.ctrl_rho, c.route_rho, c.fits
                ));
            }
            format!(
                "{{\"t\":{},\"pid\":{},\"comm\":\"{}\",\"outcome\":\"{}\",\"from\":{},\
                 \"chosen\":{},\"dist_best\":{},\"cands\":[{cands}]}}",
                r.t_ms,
                r.pid,
                esc(&r.comm),
                esc(&r.outcome),
                r.from,
                r.chosen.map(|n| n.to_string()).unwrap_or_else(|| "null".to_string()),
                r.dist_best,
            )
        }
    }
}

impl MetricsDiff {
    /// Whether anything at all diverged.
    pub fn divergent(&self) -> bool {
        !self.header.is_empty()
            || self.epochs_a != self.epochs_b
            || self.explains_a != self.explains_b
            || !self.counters.is_empty()
            || !self.gauges.is_empty()
            || !self.hists.is_empty()
            || self.explain_split.is_some()
            || !self.results.is_empty()
    }

    /// Human-readable ranked report. Byte-identical for identical input.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("insight diff (metrics): {} vs {}\n", self.a_label, self.b_label));
        out.push_str(&format!(
            "epochs: a={} b={}   explains: a={} b={}\n",
            self.epochs_a, self.epochs_b, self.explains_a, self.explains_b
        ));
        for h in &self.header {
            out.push_str(&format!("header {}: a={} b={}\n", h.field, h.a, h.b));
        }
        if !self.divergent() {
            out.push_str("no divergences\n");
            return out;
        }
        if let Some(s) = &self.explain_split {
            out.push_str(&format!(
                "decision split at explain row {} — both candidate tables:\n",
                s.index
            ));
            render_explain("a", &s.a, &mut out);
            render_explain("b", &s.b, &mut out);
        }
        if !self.counters.is_empty() {
            out.push_str("counters (ranked by first divergent epoch, then magnitude):\n");
            out.push_str("  name                        first_epoch  t_ms      a@        b@        a_final   b_final\n");
            for c in &self.counters {
                out.push_str(&format!(
                    "  {:<26}  {:<11}  {:<8}  {:<8}  {:<8}  {:<8}  {}\n",
                    c.name, c.first_epoch, c.t_ms, c.a_at, c.b_at, c.a_final, c.b_final
                ));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("gauges:\n");
            for g in &self.gauges {
                out.push_str(&format!(
                    "  {:<26}  first_epoch={}  a={}  b={}\n",
                    g.name, g.first_epoch, g.a_at, g.b_at
                ));
            }
        }
        if !self.hists.is_empty() {
            out.push_str("histograms:\n");
            for h in &self.hists {
                out.push_str(&format!(
                    "  {:<26}  first_epoch={}  a: n={} sum={}  b: n={} sum={}\n",
                    h.name, h.first_epoch, h.a_n, h.a_sum, h.b_n, h.b_sum
                ));
            }
        }
        if !self.results.is_empty() {
            out.push_str(&format!(
                "degradation deltas (policy a={}, b={}):\n",
                self.policy_a, self.policy_b
            ));
            for r in &self.results {
                out.push_str(&format!(
                    "  pid={:<6} {:<16}  a={}  b={}\n",
                    r.pid,
                    r.comm,
                    opt_f64(r.a_degradation),
                    opt_f64(r.b_degradation)
                ));
            }
        }
        out
    }

    /// `numasched-insight/v1` JSON report.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\":\"{INSIGHT_SCHEMA}\",\"verb\":\"diff\",\"kind\":\"metrics\",\
             \"a\":\"{}\",\"b\":\"{}\",\"divergent\":{},",
            esc(&self.a_label),
            esc(&self.b_label),
            self.divergent()
        ));
        out.push_str(&format!(
            "\"epochs\":{{\"a\":{},\"b\":{}}},\"explains\":{{\"a\":{},\"b\":{}}},",
            self.epochs_a, self.epochs_b, self.explains_a, self.explains_b
        ));
        out.push_str("\"header\":[");
        for (i, h) in self.header.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"field\":\"{}\",\"a\":\"{}\",\"b\":\"{}\"}}",
                h.field,
                esc(&h.a),
                esc(&h.b)
            ));
        }
        out.push_str("],\"counters\":[");
        for (i, c) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"first_epoch\":{},\"t_ms\":{},\"a_at\":{},\"b_at\":{},\
                 \"a_final\":{},\"b_final\":{}}}",
                esc(&c.name),
                c.first_epoch,
                c.t_ms,
                c.a_at,
                c.b_at,
                c.a_final,
                c.b_final
            ));
        }
        out.push_str("],\"gauges\":[");
        for (i, g) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"first_epoch\":{},\"a\":{},\"b\":{}}}",
                esc(&g.name),
                g.first_epoch,
                g.a_at,
                g.b_at
            ));
        }
        out.push_str("],\"hists\":[");
        for (i, h) in self.hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"first_epoch\":{},\"a_n\":{},\"a_sum\":{},\"b_n\":{},\
                 \"b_sum\":{}}}",
                esc(&h.name),
                h.first_epoch,
                h.a_n,
                h.a_sum,
                h.b_n,
                h.b_sum
            ));
        }
        out.push_str("],\"explain_split\":");
        match &self.explain_split {
            None => out.push_str("null"),
            Some(s) => {
                out.push_str(&format!(
                    "{{\"index\":{},\"a\":{},\"b\":{}}}",
                    s.index,
                    json_explain(&s.a),
                    json_explain(&s.b)
                ));
            }
        }
        out.push_str(",\"results\":[");
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let f = |v: Option<f64>| match v {
                Some(x) => format!("{x}"),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "{{\"pid\":{},\"comm\":\"{}\",\"a\":{},\"b\":{}}}",
                r.pid,
                esc(&r.comm),
                f(r.a_degradation),
                f(r.b_degradation)
            ));
        }
        out.push_str("]}");
        out.push('\n');
        out
    }
}

// ------------------------------------------------------------------ trace

/// First divergence in one of a trace's record sequences, with both
/// records rendered compactly. `None` = that side ran out of records.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SeqSplit {
    pub index: usize,
    pub a: Option<String>,
    pub b: Option<String>,
}

/// The full trace-diff report.
#[derive(Debug, Default)]
pub struct TraceDiffReport {
    pub a_label: String,
    pub b_label: String,
    pub header: Vec<FieldDiff>,
    pub events_a: usize,
    pub events_b: usize,
    pub event_split: Option<SeqSplit>,
    pub decisions_a: usize,
    pub decisions_b: usize,
    pub decision_split: Option<SeqSplit>,
    pub occ_a: usize,
    pub occ_b: usize,
    pub occ_split: Option<SeqSplit>,
    pub summary: Vec<FieldDiff>,
}

fn seq_split<T: PartialEq, F: Fn(&T) -> String>(a: &[T], b: &[T], render: F) -> Option<SeqSplit> {
    let common = a.len().min(b.len());
    for i in 0..common {
        if a[i] != b[i] {
            return Some(SeqSplit { index: i, a: Some(render(&a[i])), b: Some(render(&b[i])) });
        }
    }
    if a.len() != b.len() {
        return Some(SeqSplit {
            index: common,
            a: a.get(common).map(&render),
            b: b.get(common).map(&render),
        });
    }
    None
}

fn render_event(e: &super::load::TraceEvent) -> String {
    let pids: Vec<String> = e.pids.iter().map(|p| p.to_string()).collect();
    format!(
        "t={} ev={} comm={} pids=[{}] node={} pages={}",
        e.t,
        e.kind,
        e.comm,
        pids.join(","),
        opt_u64(e.node),
        opt_u64(e.pages)
    )
}

fn render_decision(d: &super::load::TraceDecision) -> String {
    format!(
        "t={} decision={} pid={} comm={} from={} to={} sticky_pages={}",
        d.t, d.reason, d.pid, d.comm, d.from, d.to, d.sticky_pages
    )
}

fn render_occ(o: &super::load::TraceOcc) -> String {
    let occ: Vec<String> = o.occ.iter().map(|x| x.to_string()).collect();
    let rho: Vec<String> = o.rho.iter().map(|x| format!("{x}")).collect();
    format!("t={} occ=[{}] rho=[{}] running={}", o.t, occ.join(","), rho.join(","), o.running)
}

fn diff_trace_headers(a: &TraceDoc, b: &TraceDoc) -> Vec<FieldDiff> {
    let mut out = Vec::new();
    let mut push = |field, x: &str, y: &str| {
        if x != y {
            out.push(FieldDiff { field, a: x.to_string(), b: y.to_string() });
        }
    };
    push("scenario", &a.scenario, &b.scenario);
    push("preset", &a.preset, &b.preset);
    push("policy", &a.policy, &b.policy);
    push("seed", &a.seed.to_string(), &b.seed.to_string());
    push("horizon_ms", &format!("{}", a.horizon_ms), &format!("{}", b.horizon_ms));
    out
}

fn diff_trace_summaries(a: &TraceDoc, b: &TraceDoc) -> Vec<FieldDiff> {
    let sa = match &a.summary {
        Some(s) => s,
        None => return Vec::new(),
    };
    let sb = match &b.summary {
        Some(s) => s,
        None => return Vec::new(),
    };
    let fields: [(&'static str, String, String); 6] = [
        ("end_ms", format!("{}", sa.end_ms), format!("{}", sb.end_ms)),
        ("procs", sa.procs.to_string(), sb.procs.to_string()),
        ("finished", sa.finished.to_string(), sb.finished.to_string()),
        ("migrations", sa.migrations.to_string(), sb.migrations.to_string()),
        ("pages_migrated", sa.pages_migrated.to_string(), sb.pages_migrated.to_string()),
        ("decisions", sa.decisions.to_string(), sb.decisions.to_string()),
    ];
    fields
        .into_iter()
        .filter(|(_, x, y)| x != y)
        .map(|(field, a, b)| FieldDiff { field, a, b })
        .collect()
}

/// Diff two parsed scenario traces.
pub fn diff_trace(a_label: &str, a: &TraceDoc, b_label: &str, b: &TraceDoc) -> TraceDiffReport {
    TraceDiffReport {
        a_label: a_label.to_string(),
        b_label: b_label.to_string(),
        header: diff_trace_headers(a, b),
        events_a: a.events.len(),
        events_b: b.events.len(),
        event_split: seq_split(&a.events, &b.events, render_event),
        decisions_a: a.decisions.len(),
        decisions_b: b.decisions.len(),
        decision_split: seq_split(&a.decisions, &b.decisions, render_decision),
        occ_a: a.occupancy.len(),
        occ_b: b.occupancy.len(),
        occ_split: seq_split(&a.occupancy, &b.occupancy, render_occ),
        summary: diff_trace_summaries(a, b),
    }
}

fn render_split(title: &str, s: &Option<SeqSplit>, out: &mut String) {
    if let Some(s) = s {
        out.push_str(&format!("{title} split at index {}:\n", s.index));
        out.push_str(&format!("  a: {}\n", s.a.as_deref().unwrap_or("<absent>")));
        out.push_str(&format!("  b: {}\n", s.b.as_deref().unwrap_or("<absent>")));
    }
}

fn json_split(s: &Option<SeqSplit>) -> String {
    match s {
        None => "null".to_string(),
        Some(s) => {
            let side = |v: &Option<String>| match v {
                Some(x) => format!("\"{}\"", esc(x)),
                None => "null".to_string(),
            };
            format!("{{\"index\":{},\"a\":{},\"b\":{}}}", s.index, side(&s.a), side(&s.b))
        }
    }
}

impl TraceDiffReport {
    pub fn divergent(&self) -> bool {
        !self.header.is_empty()
            || self.event_split.is_some()
            || self.decision_split.is_some()
            || self.occ_split.is_some()
            || !self.summary.is_empty()
    }

    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("insight diff (trace): {} vs {}\n", self.a_label, self.b_label));
        out.push_str(&format!(
            "events: a={} b={}   decisions: a={} b={}   occupancy: a={} b={}\n",
            self.events_a, self.events_b, self.decisions_a, self.decisions_b, self.occ_a,
            self.occ_b
        ));
        for h in &self.header {
            out.push_str(&format!("header {}: a={} b={}\n", h.field, h.a, h.b));
        }
        if !self.divergent() {
            out.push_str("no divergences\n");
            return out;
        }
        render_split("decision", &self.decision_split, &mut out);
        render_split("event", &self.event_split, &mut out);
        render_split("occupancy", &self.occ_split, &mut out);
        for s in &self.summary {
            out.push_str(&format!("summary {}: a={} b={}\n", s.field, s.a, s.b));
        }
        out
    }

    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\":\"{INSIGHT_SCHEMA}\",\"verb\":\"diff\",\"kind\":\"trace\",\
             \"a\":\"{}\",\"b\":\"{}\",\"divergent\":{},",
            esc(&self.a_label),
            esc(&self.b_label),
            self.divergent()
        ));
        out.push_str(&format!(
            "\"events\":{{\"a\":{},\"b\":{}}},\"decisions\":{{\"a\":{},\"b\":{}}},\
             \"occupancy\":{{\"a\":{},\"b\":{}}},",
            self.events_a, self.events_b, self.decisions_a, self.decisions_b, self.occ_a,
            self.occ_b
        ));
        out.push_str("\"header\":[");
        for (i, h) in self.header.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"field\":\"{}\",\"a\":\"{}\",\"b\":\"{}\"}}",
                h.field,
                esc(&h.a),
                esc(&h.b)
            ));
        }
        out.push_str("],");
        out.push_str(&format!("\"event_split\":{},", json_split(&self.event_split)));
        out.push_str(&format!("\"decision_split\":{},", json_split(&self.decision_split)));
        out.push_str(&format!("\"occ_split\":{},", json_split(&self.occ_split)));
        out.push_str("\"summary\":[");
        for (i, s) in self.summary.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"field\":\"{}\",\"a\":\"{}\",\"b\":\"{}\"}}",
                s.field,
                esc(&s.a),
                esc(&s.b)
            ));
        }
        out.push_str("]}");
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::load::{parse_metrics, parse_trace};
    use super::*;

    fn stream(seed: u64, moves: u64) -> String {
        format!(
            concat!(
                "{{\"schema\":\"numasched-metrics/v1\",\"name\":\"s\",\"policy\":\"proposed\",\"seed\":{seed}}}\n",
                "{{\"t\":50,\"epoch\":0,\"c\":{{\"moves\":0}},\"g\":{{\"imbalance\":0.5}},\"h\":{{}}}}\n",
                "{{\"t\":100,\"epoch\":1,\"c\":{{\"moves\":{moves}}},\"g\":{{\"imbalance\":0.5}},\"h\":{{}}}}\n",
                "{{\"end_ms\":100,\"epochs\":2,\"explains\":0}}\n",
            ),
            seed = seed,
            moves = moves
        )
    }

    #[test]
    fn identical_streams_report_no_divergences() {
        let a = parse_metrics(&stream(42, 3)).unwrap();
        let b = parse_metrics(&stream(42, 3)).unwrap();
        let d = diff_metrics("a", &a, "b", &b);
        assert!(!d.divergent());
        assert!(d.render_text().contains("no divergences"));
        assert!(d.to_json().contains("\"divergent\":false"));
    }

    #[test]
    fn counter_divergence_is_found_and_anchored() {
        let a = parse_metrics(&stream(42, 3)).unwrap();
        let b = parse_metrics(&stream(7, 9)).unwrap();
        let d = diff_metrics("a", &a, "b", &b);
        assert!(d.divergent());
        assert_eq!(d.header.len(), 1, "seed differs");
        assert_eq!(d.counters.len(), 1);
        assert_eq!(d.counters[0].name, "moves");
        assert_eq!(d.counters[0].first_epoch, 1);
        assert_eq!(d.counters[0].t_ms, 100);
        assert_eq!(d.counters[0].a_at, 3);
        assert_eq!(d.counters[0].b_at, 9);
        let text = d.render_text();
        assert!(text.contains("moves"));
        assert!(!text.contains("no divergences"));
    }

    #[test]
    fn counter_ranking_puts_earlier_then_larger_first() {
        let mk = |c0: (u64, u64), c1: (u64, u64)| {
            parse_metrics(&format!(
                concat!(
                    "{{\"schema\":\"numasched-metrics/v1\",\"name\":\"s\",\"policy\":\"p\",\"seed\":1}}\n",
                    "{{\"t\":50,\"epoch\":0,\"c\":{{\"early\":{},\"late\":0,\"big\":0}},\"g\":{{}},\"h\":{{}}}}\n",
                    "{{\"t\":100,\"epoch\":1,\"c\":{{\"early\":{},\"late\":{},\"big\":{}}},\"g\":{{}},\"h\":{{}}}}\n",
                ),
                c0.0, c0.1, c1.0, c1.1
            ))
            .unwrap()
        };
        let a = mk((1, 1), (1, 1));
        let b = mk((2, 2), (5, 100));
        let d = diff_metrics("a", &a, "b", &b);
        let names: Vec<&str> = d.counters.iter().map(|c| c.name.as_str()).collect();
        // "early" diverges at epoch 0; "big" and "late" at epoch 1 with
        // "big" carrying the larger final delta.
        assert_eq!(names, vec!["early", "big", "late"]);
    }

    #[test]
    fn trace_diff_finds_first_decision_split() {
        let mk = |to: u64| {
            parse_trace(&format!(
                concat!(
                    "{{\"schema\":\"numasched-trace/v1\",\"scenario\":\"s\",\"preset\":\"p\",",
                    "\"policy\":\"proposed\",\"seed\":1,\"horizon_ms\":1000,\"events\":0}}\n",
                    "{{\"t\":500,\"decision\":\"speedup\",\"pid\":1,\"comm\":\"w\",\"from\":0,\"to\":{to},\"sticky_pages\":0}}\n",
                    "{{\"end_ms\":1000,\"procs\":1,\"finished\":1,\"migrations\":{to},\"pages_migrated\":0,\"decisions\":1}}\n",
                ),
                to = to
            ))
            .unwrap()
        };
        let same = diff_trace("x", &mk(1), "y", &mk(1));
        assert!(!same.divergent());
        assert!(same.render_text().contains("no divergences"));

        let d = diff_trace("x", &mk(1), "y", &mk(2));
        assert!(d.divergent());
        let split = d.decision_split.as_ref().unwrap();
        assert_eq!(split.index, 0);
        assert!(split.a.as_deref().unwrap().contains("to=1"));
        assert!(split.b.as_deref().unwrap().contains("to=2"));
        assert_eq!(d.summary.len(), 1, "migrations differ in the summary");
        assert!(d.to_json().contains("\"decision_split\":{\"index\":0"));
    }

    #[test]
    fn renders_are_byte_identical_across_invocations() {
        let a = parse_metrics(&stream(42, 3)).unwrap();
        let b = parse_metrics(&stream(7, 9)).unwrap();
        let d1 = diff_metrics("a", &a, "b", &b);
        let d2 = diff_metrics("a", &a, "b", &b);
        assert_eq!(d1.render_text(), d2.render_text());
        assert_eq!(d1.to_json(), d2.to_json());
    }
}
