//! Artifact loaders: parse every schema the system emits back into
//! typed documents.
//!
//! The writers live next to their subsystems (`scenario::trace`,
//! `telemetry`, `experiments::bench_suite`); the readers live here so
//! one module owns the compatibility story. Parsing is line-oriented
//! and lenient about *order* but strict about *shape*: an unrecognized
//! record is a [`LoadError`] with its line number, not a skip — a
//! half-understood artifact would silently corrupt a diff.

use crate::telemetry::flight::FLIGHT_SCHEMA;
use crate::telemetry::provenance::is_explain_line;
use crate::telemetry::registry::{json_str, json_u64, parse_epoch_line, ParsedEpoch};
use crate::telemetry::spans::is_timing_line;
use crate::telemetry::METRICS_SCHEMA;

use super::LoadError;

/// Which artifact family a file belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Trace,
    Metrics,
    Flight,
    BenchPerf,
    BenchHistory,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Trace => "trace",
            Kind::Metrics => "metrics",
            Kind::Flight => "flight",
            Kind::BenchPerf => "bench-perf",
            Kind::BenchHistory => "bench-history",
        }
    }
}

/// Sniff the artifact kind from the first meaningful lines. The schema
/// tag is always in the header record; pretty-printed bench snapshots
/// open with a bare `{`, so a few leading lines are examined.
pub fn detect_kind(text: &str) -> Result<Kind, LoadError> {
    for line in text.lines().take(4) {
        let t = line.trim();
        if t.is_empty() || t == "{" {
            continue;
        }
        if t.contains("numasched-trace/v1") {
            return Ok(Kind::Trace);
        }
        if t.contains(METRICS_SCHEMA) {
            return Ok(Kind::Metrics);
        }
        if t.contains(FLIGHT_SCHEMA) {
            return Ok(Kind::Flight);
        }
        if t.contains("numasched-bench-perf/v1") {
            return Ok(Kind::BenchPerf);
        }
        if t.contains(super::bench::HISTORY_SCHEMA) {
            return Ok(Kind::BenchHistory);
        }
        break;
    }
    Err(LoadError { surface: "artifact", line: 1, detail: "no recognized schema tag" })
}

/// Scalar f64 field `"key":1.5` anywhere at top level of the line.
/// Returns `None` for `null` (and for a missing key), which is exactly
/// the `runtime_ms` daemon semantics.
pub fn json_f64(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Scalar i64 field (pids can in principle be negative).
pub fn json_i64(line: &str, key: &str) -> Option<i64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '-'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Scalar bool field `"key":true`.
pub fn json_bool(line: &str, key: &str) -> Option<bool> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    if rest.starts_with("true") {
        Some(true)
    } else if rest.starts_with("false") {
        Some(false)
    } else {
        None
    }
}

/// Body of the array field `"key":[...]` (no nested arrays in any of
/// our schemas, so the first `]` closes it).
pub fn bracket_body<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":[");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find(']')?;
    Some(&line[start..start + end])
}

fn parse_u64_list(body: &str) -> Option<Vec<u64>> {
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',').map(|s| s.trim().parse().ok()).collect()
}

fn parse_i64_list(body: &str) -> Option<Vec<i64>> {
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',').map(|s| s.trim().parse().ok()).collect()
}

fn parse_f64_list(body: &str) -> Option<Vec<f64>> {
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',').map(|s| s.trim().parse().ok()).collect()
}

// ---------------------------------------------------------------- metrics

/// One candidate node from an explain record's `cands` table — the full
/// term set, unlike `telemetry::provenance::ParsedExplain` which only
/// keeps the count.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    pub node: u64,
    pub distance: f64,
    pub score: f64,
    pub ctrl_rho: f64,
    pub route_rho: f64,
    pub fits: bool,
}

/// A fully-parsed explain record, candidate table included. Field-level
/// equality is what the differ uses to find the first decision split.
#[derive(Clone, Debug, PartialEq)]
pub struct ExplainRecord {
    pub t_ms: u64,
    pub pid: i64,
    pub comm: String,
    pub outcome: String,
    pub from: u64,
    pub chosen: Option<u64>,
    pub dist_best: u64,
    pub candidates: Vec<Candidate>,
}

fn parse_candidate(obj: &str) -> Option<Candidate> {
    Some(Candidate {
        node: json_u64(obj, "n")?,
        distance: json_f64(obj, "d")?,
        score: json_f64(obj, "s")?,
        ctrl_rho: json_f64(obj, "rho")?,
        route_rho: json_f64(obj, "lrho")?,
        fits: json_bool(obj, "fits")?,
    })
}

fn parse_candidates(body: &str) -> Option<Vec<Candidate>> {
    if body.is_empty() {
        return Some(Vec::new());
    }
    let inner = body.strip_prefix('{')?.strip_suffix('}')?;
    inner.split("},{").map(parse_candidate).collect()
}

/// Parse one explain record including its whole candidate table.
pub fn parse_explain_full(line: &str) -> Option<ExplainRecord> {
    if !is_explain_line(line) {
        return None;
    }
    let chosen = if line.contains("\"chosen\":null") {
        None
    } else {
        Some(json_u64(line, "chosen")?)
    };
    Some(ExplainRecord {
        t_ms: json_u64(line, "t")?,
        pid: json_i64(line, "pid")?,
        comm: json_str(line, "comm")?.to_string(),
        outcome: json_str(line, "explain")?.to_string(),
        from: json_u64(line, "from")?,
        chosen,
        dist_best: json_u64(line, "dist_best")?,
        candidates: parse_candidates(bracket_body(line, "cands")?)?,
    })
}

/// One per-process outcome record (`{"result":"proc",...}`), emitted at
/// the end of an instrumented run. `runtime_ms` is `None` for daemons
/// still running at the horizon; `degradation` is `1 / mean_speed`.
#[derive(Clone, Debug, PartialEq)]
pub struct ProcOutcome {
    pub pid: i64,
    pub comm: String,
    pub runtime_ms: Option<f64>,
    pub mean_speed: f64,
    pub degradation: f64,
    pub migrations: u64,
}

fn parse_result_line(line: &str) -> Option<ProcOutcome> {
    Some(ProcOutcome {
        pid: json_i64(line, "pid")?,
        comm: json_str(line, "comm")?.to_string(),
        runtime_ms: json_f64(line, "runtime_ms"),
        mean_speed: json_f64(line, "mean_speed")?,
        degradation: json_f64(line, "degradation")?,
        migrations: json_u64(line, "migrations")?,
    })
}

/// A whole `numasched-metrics/v1` stream, classified and parsed.
/// Timing records are skipped by design: they carry the one wall-clock
/// value in the stream and must never reach a diff.
#[derive(Debug, Default)]
pub struct MetricsDoc {
    pub name: String,
    pub policy: String,
    pub seed: u64,
    pub epochs: Vec<ParsedEpoch>,
    pub explains: Vec<ExplainRecord>,
    pub results: Vec<ProcOutcome>,
    pub end_ms: Option<u64>,
}

pub fn parse_metrics(text: &str) -> Result<MetricsDoc, LoadError> {
    const SURFACE: &str = "metrics stream";
    let mut doc = MetricsDoc::default();
    let mut saw_header = false;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let bad = |detail| LoadError { surface: SURFACE, line: lineno, detail };
        if line.trim().is_empty() {
            continue;
        }
        if line.contains(METRICS_SCHEMA) {
            doc.name =
                json_str(line, "name").ok_or_else(|| bad("header missing name"))?.to_string();
            doc.policy =
                json_str(line, "policy").ok_or_else(|| bad("header missing policy"))?.to_string();
            doc.seed = json_u64(line, "seed").ok_or_else(|| bad("header missing seed"))?;
            saw_header = true;
        } else if is_timing_line(line) {
            // Wall-clock record: excluded from analysis, like the
            // determinism gate excludes it from byte-diffs.
        } else if is_explain_line(line) {
            doc.explains.push(parse_explain_full(line).ok_or_else(|| bad("bad explain record"))?);
        } else if line.starts_with("{\"t\":") && line.contains("\"epoch\":") {
            doc.epochs.push(parse_epoch_line(line).ok_or_else(|| bad("bad epoch record"))?);
        } else if line.starts_with("{\"result\":") {
            doc.results.push(parse_result_line(line).ok_or_else(|| bad("bad result record"))?);
        } else if line.starts_with("{\"end_ms\":") {
            doc.end_ms = Some(json_u64(line, "end_ms").ok_or_else(|| bad("bad footer record"))?);
        } else {
            return Err(bad("unrecognized metrics record"));
        }
    }
    if !saw_header {
        return Err(LoadError { surface: SURFACE, line: 1, detail: "missing stream header" });
    }
    Ok(doc)
}

// ------------------------------------------------------------------ trace

/// One fired timeline event from a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub kind: String,
    pub comm: String,
    pub pids: Vec<i64>,
    pub node: Option<u64>,
    pub pages: Option<u64>,
}

/// One executed scheduler decision from a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceDecision {
    pub t: f64,
    pub reason: String,
    pub pid: i64,
    pub comm: String,
    pub from: u64,
    pub to: u64,
    pub sticky_pages: u64,
}

/// One periodic occupancy sample from a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceOcc {
    pub t: f64,
    pub occ: Vec<u64>,
    pub rho: Vec<f64>,
    pub running: u64,
}

/// The closing summary record of a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSummary {
    pub end_ms: f64,
    pub procs: u64,
    pub finished: u64,
    pub migrations: u64,
    pub pages_migrated: u64,
    pub decisions: u64,
}

/// A whole `numasched-trace/v1` file, record-classified.
#[derive(Debug, Default)]
pub struct TraceDoc {
    pub scenario: String,
    pub preset: String,
    pub policy: String,
    pub seed: u64,
    pub horizon_ms: f64,
    pub events: Vec<TraceEvent>,
    pub decisions: Vec<TraceDecision>,
    pub occupancy: Vec<TraceOcc>,
    pub summary: Option<TraceSummary>,
}

fn parse_trace_event(line: &str) -> Option<TraceEvent> {
    Some(TraceEvent {
        t: json_f64(line, "t")?,
        kind: json_str(line, "ev")?.to_string(),
        comm: json_str(line, "comm")?.to_string(),
        pids: parse_i64_list(bracket_body(line, "pids")?)?,
        node: json_u64(line, "node"),
        pages: json_u64(line, "pages"),
    })
}

fn parse_trace_decision(line: &str) -> Option<TraceDecision> {
    Some(TraceDecision {
        t: json_f64(line, "t")?,
        reason: json_str(line, "decision")?.to_string(),
        pid: json_i64(line, "pid")?,
        comm: json_str(line, "comm")?.to_string(),
        from: json_u64(line, "from")?,
        to: json_u64(line, "to")?,
        sticky_pages: json_u64(line, "sticky_pages")?,
    })
}

fn parse_trace_occ(line: &str) -> Option<TraceOcc> {
    Some(TraceOcc {
        t: json_f64(line, "t")?,
        occ: parse_u64_list(bracket_body(line, "occ")?)?,
        rho: parse_f64_list(bracket_body(line, "rho")?)?,
        running: json_u64(line, "running")?,
    })
}

fn parse_trace_summary(line: &str) -> Option<TraceSummary> {
    Some(TraceSummary {
        end_ms: json_f64(line, "end_ms")?,
        procs: json_u64(line, "procs")?,
        finished: json_u64(line, "finished")?,
        migrations: json_u64(line, "migrations")?,
        pages_migrated: json_u64(line, "pages_migrated")?,
        decisions: json_u64(line, "decisions")?,
    })
}

pub fn parse_trace(text: &str) -> Result<TraceDoc, LoadError> {
    const SURFACE: &str = "scenario trace";
    let mut doc = TraceDoc::default();
    let mut saw_header = false;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let bad = |detail| LoadError { surface: SURFACE, line: lineno, detail };
        if line.trim().is_empty() {
            continue;
        }
        if line.contains("\"schema\":\"numasched-trace/v1\"") {
            let sc = json_str(line, "scenario").ok_or_else(|| bad("header missing scenario"))?;
            doc.scenario = sc.to_string();
            doc.preset =
                json_str(line, "preset").ok_or_else(|| bad("header missing preset"))?.to_string();
            doc.policy =
                json_str(line, "policy").ok_or_else(|| bad("header missing policy"))?.to_string();
            doc.seed = json_u64(line, "seed").ok_or_else(|| bad("header missing seed"))?;
            doc.horizon_ms =
                json_f64(line, "horizon_ms").ok_or_else(|| bad("header missing horizon_ms"))?;
            saw_header = true;
        } else if line.contains("\"ev\":\"") {
            doc.events.push(parse_trace_event(line).ok_or_else(|| bad("bad event record"))?);
        } else if line.contains("\"decision\":\"") {
            doc.decisions
                .push(parse_trace_decision(line).ok_or_else(|| bad("bad decision record"))?);
        } else if line.contains("\"occ\":[") {
            doc.occupancy.push(parse_trace_occ(line).ok_or_else(|| bad("bad occupancy record"))?);
        } else if line.starts_with("{\"end_ms\":") {
            doc.summary = Some(parse_trace_summary(line).ok_or_else(|| bad("bad summary record"))?);
        } else {
            return Err(bad("unrecognized trace record"));
        }
    }
    if !saw_header {
        return Err(LoadError { surface: SURFACE, line: 1, detail: "missing trace header" });
    }
    Ok(doc)
}

// ----------------------------------------------------------------- flight

/// A parsed flight-recorder dump: the trigger header plus the retained
/// tail of the metrics stream (epochs + explains), reusing
/// [`MetricsDoc`] so timelines work on dumps unchanged.
#[derive(Debug, Default)]
pub struct FlightDoc {
    pub reason: String,
    pub frames: u64,
    pub total_epochs: u64,
    /// Epochs that rolled off the ring before the dump. Older dumps
    /// lack the field; it is then derived as `total_epochs - frames`.
    pub evicted: u64,
    pub metrics: MetricsDoc,
}

pub fn parse_flight(text: &str) -> Result<FlightDoc, LoadError> {
    const SURFACE: &str = "flight dump";
    let mut doc = FlightDoc::default();
    let mut saw_header = false;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let bad = |detail| LoadError { surface: SURFACE, line: lineno, detail };
        if line.trim().is_empty() {
            continue;
        }
        if line.contains(FLIGHT_SCHEMA) {
            let frames = json_u64(line, "frames").ok_or_else(|| bad("header missing frames"))?;
            let total =
                json_u64(line, "total_epochs").ok_or_else(|| bad("header missing total_epochs"))?;
            doc.reason =
                json_str(line, "reason").ok_or_else(|| bad("header missing reason"))?.to_string();
            doc.frames = frames;
            doc.total_epochs = total;
            doc.evicted =
                json_u64(line, "evicted").unwrap_or_else(|| total.saturating_sub(frames));
            doc.metrics.name = doc.reason.clone();
            saw_header = true;
        } else if is_explain_line(line) {
            doc.metrics
                .explains
                .push(parse_explain_full(line).ok_or_else(|| bad("bad explain record"))?);
        } else if line.starts_with("{\"t\":") && line.contains("\"epoch\":") {
            doc.metrics.epochs.push(parse_epoch_line(line).ok_or_else(|| bad("bad epoch record"))?);
        } else {
            return Err(bad("unrecognized flight record"));
        }
    }
    if !saw_header {
        return Err(LoadError { surface: SURFACE, line: 1, detail: "missing dump header" });
    }
    Ok(doc)
}

// ------------------------------------------------------------- bench perf

/// A flattened `BENCH_PERF.json`: every numeric leaf keyed as
/// `section.name`, plus the `smoke` / `provisional` markers. String
/// leaves (the scale preset) and per-section `identical` flags carry no
/// perf signal and are dropped.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchDoc {
    pub smoke: bool,
    pub provisional: bool,
    pub metrics: Vec<(String, f64)>,
}

/// Parse the pretty-printed bench snapshot with a line scanner — the
/// emitter (`BenchReport::to_json`) nests exactly one level deep, so
/// `"key": {` opens a section and a leading `}` closes it.
pub fn parse_bench_perf(text: &str) -> Result<BenchDoc, LoadError> {
    const SURFACE: &str = "bench snapshot";
    if !text.contains("numasched-bench-perf/v1") {
        return Err(LoadError { surface: SURFACE, line: 1, detail: "missing schema tag" });
    }
    let mut doc = BenchDoc::default();
    let mut section: Option<String> = None;
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let t = raw.trim();
        if t.is_empty() || t == "{" {
            continue;
        }
        if t.starts_with('}') {
            section = None;
            continue;
        }
        let Some(rest) = t.strip_prefix('"') else {
            return Err(LoadError { surface: SURFACE, line: lineno, detail: "expected a key" });
        };
        let Some((key, after)) = rest.split_once('"') else {
            return Err(LoadError { surface: SURFACE, line: lineno, detail: "unterminated key" });
        };
        let value = after.trim_start_matches(':').trim().trim_end_matches(',').trim();
        if value == "{" {
            section = Some(key.to_string());
        } else if value == "true" || value == "false" {
            match key {
                "smoke" => doc.smoke = value == "true",
                "provisional" => doc.provisional = value == "true",
                _ => {} // identical / allocs_counted: not perf metrics
            }
        } else if value.starts_with('"') {
            // String leaf (schema tag, scale preset): no perf signal.
        } else if let Ok(v) = value.parse::<f64>() {
            let name = match &section {
                Some(s) => format!("{s}.{key}"),
                None => key.to_string(),
            };
            doc.metrics.push((name, v));
        } else {
            return Err(LoadError { surface: SURFACE, line: lineno, detail: "unparseable value" });
        }
    }
    if doc.metrics.is_empty() {
        return Err(LoadError { surface: SURFACE, line: 1, detail: "no numeric metrics" });
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_helpers_parse_and_reject() {
        let line = "{\"a\":-3,\"b\":2.5,\"c\":null,\"d\":true,\"v\":[1,2],\"f\":[0.5]}";
        assert_eq!(json_i64(line, "a"), Some(-3));
        assert_eq!(json_f64(line, "b"), Some(2.5));
        assert_eq!(json_f64(line, "c"), None, "null is absence, not zero");
        assert_eq!(json_bool(line, "d"), Some(true));
        assert_eq!(parse_u64_list(bracket_body(line, "v").unwrap()), Some(vec![1, 2]));
        assert_eq!(parse_f64_list(bracket_body(line, "f").unwrap()), Some(vec![0.5]));
        assert_eq!(json_f64(line, "zz"), None);
        assert_eq!(parse_u64_list("7,x"), None);
    }

    #[test]
    fn detect_kind_sniffs_every_schema_and_rejects_junk() {
        assert_eq!(
            detect_kind("{\"schema\":\"numasched-trace/v1\",\"scenario\":\"x\"}\n"),
            Ok(Kind::Trace)
        );
        assert_eq!(detect_kind("{\"schema\":\"numasched-metrics/v1\"}\n"), Ok(Kind::Metrics));
        assert_eq!(detect_kind("{\"schema\":\"numasched-flight/v1\"}\n"), Ok(Kind::Flight));
        assert_eq!(
            detect_kind("{\n  \"schema\": \"numasched-bench-perf/v1\",\n"),
            Ok(Kind::BenchPerf)
        );
        assert_eq!(
            detect_kind("{\"schema\":\"numasched-bench-history/v1\",\"id\":\"a\"}\n"),
            Ok(Kind::BenchHistory)
        );
        let err = detect_kind("not json at all\n").unwrap_err();
        assert_eq!(err.detail, "no recognized schema tag");
        assert!(err.to_string().contains("artifact"));
    }

    #[test]
    fn explain_full_roundtrips_the_writer() {
        use crate::telemetry::provenance::{CandidateTerm, ExplainRow};
        let row = ExplainRow {
            t_ms: 550,
            pid: 1004,
            comm: "hog-0".into(),
            from: 2,
            outcome: "moved",
            chosen: Some(3),
            distance_best: 1,
            needed: 1.06,
            cooldown: false,
            sticky_pages: 2048,
            candidates: vec![
                CandidateTerm {
                    node: 1,
                    distance: 10.0,
                    score: 1.4,
                    ctrl_rho: 0.9,
                    route_rho: 0.95,
                    fits: true,
                },
                CandidateTerm {
                    node: 3,
                    distance: 21.0,
                    score: 1.3,
                    ctrl_rho: 0.2,
                    route_rho: 0.1,
                    fits: false,
                },
            ],
        };
        let rec = parse_explain_full(&row.render_json()).expect("parse own emission");
        assert_eq!(rec.t_ms, 550);
        assert_eq!(rec.pid, 1004);
        assert_eq!(rec.comm, "hog-0");
        assert_eq!(rec.outcome, "moved");
        assert_eq!(rec.chosen, Some(3));
        assert_eq!(rec.dist_best, 1);
        assert_eq!(rec.candidates.len(), 2);
        assert_eq!(rec.candidates[0].route_rho, 0.95);
        assert_eq!(rec.candidates[0].ctrl_rho, 0.9);
        assert!(!rec.candidates[1].fits);
    }

    #[test]
    fn metrics_doc_rejects_mangled_lines_with_line_numbers() {
        let good = "{\"schema\":\"numasched-metrics/v1\",\"name\":\"x\",\"policy\":\"proposed\",\"seed\":7}\n";
        let doc = parse_metrics(good).unwrap();
        assert_eq!(doc.name, "x");
        assert_eq!(doc.seed, 7);

        let mangled = format!("{good}garbage line\n");
        let err = parse_metrics(&mangled).unwrap_err();
        assert_eq!(err.line, 2);
        assert_eq!(err.detail, "unrecognized metrics record");

        let headerless = "{\"t\":1,\"epoch\":0,\"c\":{},\"g\":{},\"h\":{}}\n";
        assert_eq!(parse_metrics(headerless).unwrap_err().detail, "missing stream header");
    }

    #[test]
    fn trace_doc_classifies_all_five_record_kinds() {
        let text = concat!(
            "{\"schema\":\"numasched-trace/v1\",\"scenario\":\"s\",\"preset\":\"2node-8core\",",
            "\"policy\":\"proposed\",\"seed\":42,\"horizon_ms\":2000,\"events\":1}\n",
            "{\"t\":100,\"ev\":\"launch\",\"comm\":\"web\",\"pids\":[1001],\"node\":1,\"pages\":50}\n",
            "{\"t\":550,\"decision\":\"speedup\",\"pid\":1001,\"comm\":\"web\",\"from\":0,\"to\":1,\"sticky_pages\":9}\n",
            "{\"t\":512.5,\"occ\":[10,20],\"rho\":[0.5,0.25],\"running\":2}\n",
            "{\"end_ms\":2000,\"procs\":2,\"finished\":1,\"migrations\":3,\"pages_migrated\":77,\"decisions\":4}\n",
        );
        let doc = parse_trace(text).unwrap();
        assert_eq!(doc.scenario, "s");
        assert_eq!(doc.horizon_ms, 2000.0);
        assert_eq!(doc.events.len(), 1);
        assert_eq!(doc.events[0].pids, vec![1001]);
        assert_eq!(doc.decisions[0].reason, "speedup");
        assert_eq!(doc.occupancy[0].t, 512.5);
        assert_eq!(doc.occupancy[0].rho, vec![0.5, 0.25]);
        assert_eq!(doc.summary.as_ref().unwrap().pages_migrated, 77);

        let err =
            parse_trace("{\"schema\":\"numasched-trace/v1\",\"scenario\":\"s\"}\n").unwrap_err();
        assert_eq!(err.detail, "header missing preset");
    }

    #[test]
    fn flight_doc_reads_header_and_tail_and_derives_evicted() {
        let text = concat!(
            "{\"schema\":\"numasched-flight/v1\",\"reason\":\"oracle\",\"frames\":1,\"total_epochs\":5}\n",
            "{\"t\":400,\"epoch\":4,\"c\":{\"moves\":2},\"g\":{},\"h\":{}}\n",
        );
        let doc = parse_flight(text).unwrap();
        assert_eq!(doc.reason, "oracle");
        assert_eq!(doc.evicted, 4, "derived from total_epochs - frames");
        assert_eq!(doc.metrics.epochs.len(), 1);

        let tagged = text.replace("\"total_epochs\":5}", "\"total_epochs\":5,\"evicted\":4}");
        assert_eq!(parse_flight(&tagged).unwrap().evicted, 4);
    }

    #[test]
    fn bench_perf_flattens_sections_and_keeps_markers() {
        let sample = concat!(
            "{\n",
            "  \"schema\": \"numasched-bench-perf/v1\",\n",
            "  \"provisional\": true,\n",
            "  \"smoke\": true,\n",
            "  \"allocs_counted\": true,\n",
            "  \"roundtrip\": {\n",
            "    \"iters\": 2000,\n",
            "    \"ns_p50\": 9000.0,\n",
            "    \"allocs_per_sample\": 0.0000\n",
            "  },\n",
            "  \"scale\": {\n",
            "    \"preset\": \"64node-fleet\",\n",
            "    \"monitor_incr_hits\": 1800,\n",
            "    \"sweep_identical\": true\n",
            "  }\n",
            "}\n",
        );
        let doc = parse_bench_perf(sample).unwrap();
        assert!(doc.smoke);
        assert!(doc.provisional);
        let get = |k: &str| doc.metrics.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("roundtrip.ns_p50"), Some(9000.0));
        assert_eq!(get("scale.monitor_incr_hits"), Some(1800.0));
        assert_eq!(get("roundtrip.allocs_per_sample"), Some(0.0));
        assert!(get("scale.preset").is_none(), "string leaves are dropped");
        assert!(get("scale.sweep_identical").is_none(), "flag leaves are dropped");

        // The committed snapshot (placeholder or CI-measured) must
        // always load — CI replaces the provisional marker, so only
        // shape is asserted here, not markers.
        let live = parse_bench_perf(include_str!("../../../BENCH_PERF.json")).unwrap();
        assert!(live.metrics.len() >= 10, "live snapshot lost its metric leaves");

        let err = parse_bench_perf("{\"other\": 1}\n").unwrap_err();
        assert_eq!(err.detail, "missing schema tag");
    }
}
