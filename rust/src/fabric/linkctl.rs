//! Per-link interconnect contention model — the link-side mirror of
//! [`MemCtl`](crate::sim::memctl::MemCtl).
//!
//! A QPI/UPI link queues exactly like a memory controller: as aggregate
//! routed demand approaches the link's bandwidth, every transfer that
//! crosses it stalls. Same M/M/1-style `rho / (1 - rho)` shape, same
//! one-tick lag (this tick's accesses are priced with the *previous*
//! tick's utilization, breaking the demand/speed fixed point), same
//! [`RHO_MAX`] saturation clip on the *pricing* side. The raw committed
//! utilization is unclipped — overload must stay visible to the monitor
//! surface, exactly as `MemCtl::rho_raw` now guarantees.

use crate::sim::memctl::RHO_MAX;

/// One interconnect link's queue state.
#[derive(Clone, Debug)]
pub struct LinkCtl {
    /// Capacity, GB/s.
    pub bandwidth_gbs: f64,
    /// Demand accumulated for the tick being computed, GB/s.
    demand: f64,
    /// Utilization committed by the previous tick (prices this tick).
    rho_prev: f64,
    /// Ticks whose committed utilization exceeded [`RHO_MAX`] — i.e.
    /// ticks where the pricing clip actually engaged. Telemetry surfaces
    /// this; the pricing math never reads it.
    clips: u64,
}

impl LinkCtl {
    pub fn new(bandwidth_gbs: f64) -> Self {
        assert!(bandwidth_gbs > 0.0);
        Self { bandwidth_gbs, demand: 0.0, rho_prev: 0.0, clips: 0 }
    }

    /// Add routed demand (GB/s) for the open tick.
    pub fn add_demand(&mut self, gbs: f64) {
        debug_assert!(gbs >= 0.0);
        self.demand += gbs;
    }

    /// Close the tick: demand becomes the next tick's priced
    /// utilization. Unclipped — see `MemCtl::commit_tick`.
    pub fn commit_tick(&mut self) {
        self.rho_prev = self.demand / self.bandwidth_gbs;
        if self.rho_prev > RHO_MAX {
            self.clips += 1;
        }
        self.demand = 0.0;
    }

    /// Number of committed ticks on which the pricing clip engaged.
    pub fn clip_count(&self) -> u64 {
        self.clips
    }

    /// Utilization in effect for pricing (clipped at saturation).
    pub fn rho(&self) -> f64 {
        self.rho_prev.min(RHO_MAX)
    }

    /// Raw (unclipped) utilization of the last committed tick — what
    /// the sysfs-like link-stats surface renders.
    pub fn rho_raw(&self) -> f64 {
        self.rho_prev
    }

    pub fn pending_demand(&self) -> f64 {
        self.demand
    }

    /// Queueing delay factor q(rho) = rho/(1-rho), clipped at RHO_MAX.
    /// The fabric latency term is `weight * q` summed over the route.
    pub fn queue_factor(&self) -> f64 {
        let rho = self.rho();
        rho / (1.0 - rho)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_link_adds_no_latency() {
        let mut c = LinkCtl::new(10.0);
        c.commit_tick();
        assert_eq!(c.queue_factor(), 0.0);
        assert_eq!(c.rho(), 0.0);
    }

    #[test]
    fn demand_prices_next_tick_with_lag() {
        let mut c = LinkCtl::new(10.0);
        c.add_demand(5.0);
        assert_eq!(c.rho(), 0.0, "lagged: open tick not yet priced");
        c.commit_tick();
        assert!((c.rho() - 0.5).abs() < 1e-12);
        assert!((c.queue_factor() - 1.0).abs() < 1e-12);
        assert_eq!(c.pending_demand(), 0.0);
    }

    #[test]
    fn saturation_clips_pricing_but_not_raw() {
        let mut c = LinkCtl::new(2.0);
        c.add_demand(20.0);
        c.commit_tick();
        assert_eq!(c.rho(), RHO_MAX);
        assert!((c.rho_raw() - 10.0).abs() < 1e-12, "raw stays unclipped");
        assert!(c.queue_factor().is_finite());
    }

    #[test]
    fn clip_counter_tracks_saturated_ticks_only() {
        let mut c = LinkCtl::new(10.0);
        c.add_demand(5.0); // rho 0.5: no clip
        c.commit_tick();
        assert_eq!(c.clip_count(), 0);
        c.add_demand(20.0); // rho 2.0: clip
        c.commit_tick();
        c.add_demand(9.5); // rho 0.95 > RHO_MAX: clip
        c.commit_tick();
        assert_eq!(c.clip_count(), 2);
        c.commit_tick(); // idle tick: no clip
        assert_eq!(c.clip_count(), 2);
    }
}
