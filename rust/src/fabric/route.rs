//! Precomputed min-hop routing over the link graph.
//!
//! Every remote access and page-migration transfer in the simulator
//! traverses a *path* of links, not a teleport: the routing table maps
//! each (src, dst) node pair to the link ids along the chosen shortest
//! path. Paths are minimum-hop; among equal-hop paths the SLIT-weighted
//! sum of per-hop distances breaks the tie (a QPI route through a
//! "close" socket beats one through a far socket, like real snoop
//! routing), and node-index order breaks any remaining tie so the table
//! is fully deterministic. Construction validates the graph and rejects
//! disconnected fabrics — a pair with no route would silently drop
//! traffic.

use super::graph::{check_symmetric, Link, LinkGraph};

/// The fabric as the rest of the system consumes it: validated link
/// graph + complete routing table + the latency-term weight.
#[derive(Clone, Debug, PartialEq)]
pub struct FabricTopology {
    pub graph: LinkGraph,
    /// Weight of the fabric latency term in the simulator tick (the
    /// link-side analogue of `memctl::QUEUE_WEIGHT`). 0 keeps the
    /// fabric observable (link load is still modeled and rendered)
    /// without adding latency.
    pub weight: f64,
    /// `routes[src * nodes + dst]` = link ids along the chosen path.
    routes: Vec<Vec<u16>>,
}

impl FabricTopology {
    /// Build and validate: graph structure, weight, symmetric SLIT, and
    /// connectivity (every pair must route).
    pub fn new(graph: LinkGraph, weight: f64, distance: &[Vec<f64>]) -> Result<Self, String> {
        graph.validate()?;
        if !weight.is_finite() || weight < 0.0 {
            return Err(format!("fabric weight {weight} must be finite and >= 0"));
        }
        let nodes = graph.nodes();
        if distance.len() != nodes || distance.iter().any(|r| r.len() != nodes) {
            return Err("fabric distance matrix shape must be nodes x nodes".into());
        }
        check_symmetric(distance)?;
        let routes = build_routes(&graph, distance)?;
        Ok(Self { graph, weight, routes })
    }

    /// Build from the config table (explicit links or the derived ring).
    pub fn from_config(
        cfg: &crate::config::FabricConfig,
        nodes: usize,
        distance: &[Vec<f64>],
    ) -> Result<Self, String> {
        let graph = match &cfg.links {
            Some(ls) => LinkGraph::explicit(
                nodes,
                ls.iter()
                    .map(|&(a, b, bandwidth_gbs)| Link { a, b, bandwidth_gbs })
                    .collect(),
            ),
            None => LinkGraph::ring(nodes, cfg.link_bandwidth_gbs),
        };
        Self::new(graph, cfg.weight, distance)
    }

    pub fn nodes(&self) -> usize {
        self.graph.nodes()
    }

    /// Number of links (the length every per-link vector must have).
    pub fn links(&self) -> usize {
        self.graph.len()
    }

    /// Link ids along the route from `a` to `b` (empty when `a == b`).
    pub fn route(&self, a: usize, b: usize) -> &[u16] {
        &self.routes[a * self.nodes() + b]
    }

    /// Hop count of the chosen route.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        self.route(a, b).len()
    }

    /// Charge a cross-node traffic matrix to the links it traverses.
    /// Returns GB/s of demand per link — the conservation property the
    /// fabric test suite pins: the total equals Σ traffic × hops.
    pub fn route_demand(&self, traffic: &[(usize, usize, f64)]) -> Vec<f64> {
        let mut out = vec![0.0; self.links()];
        for &(a, b, gbs) in traffic {
            for &l in self.route(a, b) {
                out[l as usize] += gbs;
            }
        }
        out
    }

    /// Re-check everything `new` established (topology-level validate).
    pub fn validate(&self) -> Result<(), String> {
        self.graph.validate()?;
        if !self.weight.is_finite() || self.weight < 0.0 {
            return Err(format!("fabric weight {} invalid", self.weight));
        }
        let n = self.nodes();
        if self.routes.len() != n * n {
            return Err("fabric routing table has wrong shape".into());
        }
        for a in 0..n {
            for b in 0..n {
                if a != b && self.route(a, b).is_empty() {
                    return Err(format!("no fabric route from node {a} to node {b}"));
                }
            }
        }
        Ok(())
    }
}

/// Dijkstra per source with lexicographic cost (hops, SLIT path sum)
/// and node-index tie-break. O(n^2 · links), n <= 8 — negligible, and
/// run once at construction.
fn build_routes(graph: &LinkGraph, distance: &[Vec<f64>]) -> Result<Vec<Vec<u16>>, String> {
    let n = graph.nodes();
    let mut routes = vec![Vec::new(); n * n];
    // Adjacency: (link id, neighbor) per node.
    let mut adj: Vec<Vec<(u16, usize)>> = vec![Vec::new(); n];
    for (i, l) in graph.links().iter().enumerate() {
        adj[l.a].push((i as u16, l.b));
        adj[l.b].push((i as u16, l.a));
    }
    for src in 0..n {
        let mut hops = vec![u32::MAX; n];
        let mut slit = vec![f64::INFINITY; n];
        let mut pred: Vec<Option<(usize, u16)>> = vec![None; n];
        let mut done = vec![false; n];
        hops[src] = 0;
        slit[src] = 0.0;
        loop {
            // Lowest (hops, slit, index) unvisited node.
            let mut u: Option<usize> = None;
            for v in 0..n {
                if done[v] || hops[v] == u32::MAX {
                    continue;
                }
                let better = match u {
                    None => true,
                    Some(best) => (hops[v], slit[v]) < (hops[best], slit[best]),
                };
                if better {
                    u = Some(v);
                }
            }
            let Some(u) = u else { break };
            done[u] = true;
            for &(link, v) in &adj[u] {
                let cand = (hops[u] + 1, slit[u] + distance[u][v]);
                if cand < (hops[v], slit[v]) {
                    hops[v] = cand.0;
                    slit[v] = cand.1;
                    pred[v] = Some((u, link));
                }
            }
        }
        for dst in 0..n {
            if dst == src {
                continue;
            }
            if hops[dst] == u32::MAX {
                return Err(format!(
                    "fabric link graph is disconnected: no path {src} -> {dst}"
                ));
            }
            let mut path = Vec::with_capacity(hops[dst] as usize);
            let mut cur = dst;
            while cur != src {
                let (prev, link) = pred[cur].expect("reached node has a predecessor");
                path.push(link);
                cur = prev;
            }
            path.reverse();
            routes[src * n + dst] = path;
        }
    }
    Ok(routes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::NumaTopology;

    fn ring_fabric(nodes: usize) -> FabricTopology {
        FabricTopology::new(
            LinkGraph::ring(nodes, 10.0),
            0.35,
            &NumaTopology::ring_distance(nodes, 21.0),
        )
        .unwrap()
    }

    #[test]
    fn ring_routes_are_min_hop() {
        let f = ring_fabric(8);
        for a in 0..8 {
            for b in 0..8 {
                let fwd = (b + 8 - a) % 8;
                let want = if a == b { 0 } else { fwd.min(8 - fwd) };
                assert_eq!(f.hops(a, b), want, "route {a}->{b}");
            }
        }
    }

    #[test]
    fn routes_are_deterministic() {
        let a = ring_fabric(8);
        let b = ring_fabric(8);
        for x in 0..8 {
            for y in 0..8 {
                assert_eq!(a.route(x, y), b.route(x, y));
            }
        }
    }

    #[test]
    fn disconnected_graph_rejected() {
        // Node 3 has no wire at all.
        let g = LinkGraph::explicit(
            4,
            vec![
                Link { a: 0, b: 1, bandwidth_gbs: 10.0 },
                Link { a: 1, b: 2, bandwidth_gbs: 10.0 },
            ],
        );
        let e = FabricTopology::new(g, 0.35, &NumaTopology::ring_distance(4, 21.0));
        assert!(e.is_err());
        assert!(e.unwrap_err().contains("disconnected"));
    }

    #[test]
    fn slit_breaks_equal_hop_ties() {
        // A diamond: 0-1-3 and 0-2-3 are both 2 hops, but the SLIT says
        // going through node 1 is closer. The route must take it.
        let g = LinkGraph::explicit(
            4,
            vec![
                Link { a: 0, b: 1, bandwidth_gbs: 10.0 },
                Link { a: 0, b: 2, bandwidth_gbs: 10.0 },
                Link { a: 1, b: 3, bandwidth_gbs: 10.0 },
                Link { a: 2, b: 3, bandwidth_gbs: 10.0 },
            ],
        );
        let d = vec![
            vec![10.0, 15.0, 30.0, 40.0],
            vec![15.0, 10.0, 30.0, 15.0],
            vec![30.0, 30.0, 10.0, 30.0],
            vec![40.0, 15.0, 30.0, 10.0],
        ];
        let f = FabricTopology::new(g, 0.35, &d).unwrap();
        assert_eq!(f.route(0, 3), &[0, 2], "0-1-3 is SLIT-closer than 0-2-3");
        assert_eq!(f.route(3, 0), &[2, 0], "reverse route mirrors");
    }

    #[test]
    fn route_demand_conserves_hop_weighted_traffic() {
        let f = ring_fabric(6);
        let traffic = vec![(0usize, 3usize, 4.0), (1, 2, 2.0), (5, 0, 1.0)];
        let per_link = f.route_demand(&traffic);
        let total: f64 = per_link.iter().sum();
        let want: f64 = traffic
            .iter()
            .map(|&(a, b, g)| g * f.hops(a, b) as f64)
            .sum();
        assert!((total - want).abs() < 1e-12, "{total} vs {want}");
        assert!(per_link.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn weight_validated() {
        let g = LinkGraph::ring(2, 10.0);
        let d = NumaTopology::ring_distance(2, 20.0);
        assert!(FabricTopology::new(g.clone(), -0.1, &d).is_err());
        assert!(FabricTopology::new(g.clone(), f64::NAN, &d).is_err());
        assert!(FabricTopology::new(g, 0.0, &d).is_ok(), "0 = observe-only");
    }

    #[test]
    fn asymmetric_distance_rejected() {
        let g = LinkGraph::ring(2, 10.0);
        let d = vec![vec![10.0, 21.0], vec![25.0, 10.0]];
        assert!(FabricTopology::new(g, 0.35, &d).is_err());
    }
}
