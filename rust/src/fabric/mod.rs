//! The interconnect fabric subsystem: link topology, routed congestion,
//! and the pieces fabric-aware placement builds on.
//!
//! The per-node [`MemCtl`](crate::sim::memctl::MemCtl) queueing model
//! prices *node-local* contention, but on real 4–8-socket machines the
//! second-order NUMA effect is the interconnect itself: every remote
//! access and every `migrate_pages` burst crosses QPI/UPI links of
//! finite width, and a saturated link degrades everyone routed over it
//! no matter how idle the endpoints' controllers are. This module adds
//! that layer:
//!
//! * [`graph`] — [`LinkGraph`]: undirected point-to-point links with
//!   per-link bandwidth (explicit config lists, or a derived ring
//!   consistent with `ring_distance`), plus the shared distance-matrix
//!   validation helpers `topology::validate` reuses;
//! * [`route`] — [`FabricTopology`]: a precomputed min-hop routing
//!   table (SLIT-weighted tie-break), validated connected and symmetric
//!   at construction;
//! * [`linkctl`] — [`LinkCtl`]: the M/M/1-style, one-tick-lagged
//!   per-link queue the simulator charges routed GB/s demand into.
//!
//! Layering mirrors the `mem` subsystem: topology owns the fabric shape
//! (`NumaTopology::fabric`), the simulator enforces it (`sim::machine`
//! routes demand and adds the latency term), `procfs::sysnode` renders
//! and parses a sysfs-like link-stats surface so the Monitor observes
//! link load through *text only*, and the proposed scheduler scores
//! candidate nodes with projected per-link load carried by the
//! placement ledger. Machines without a `[machine.fabric]` table get
//! `None` everywhere and run bit-identically to the pre-fabric code.

pub mod graph;
pub mod linkctl;
pub mod route;

pub use graph::{check_symmetric, Link, LinkGraph};
pub use linkctl::LinkCtl;
pub use route::FabricTopology;
