//! The interconnect link graph — which sockets are wired to which, and
//! how much each wire carries.
//!
//! A [`LinkGraph`] is a set of undirected point-to-point links with
//! per-link bandwidth (QPI/UPI lanes between sockets). It comes from an
//! explicit `links = [[a, b, gbs], ...]` list in config, or is derived
//! as a ring consistent with [`ring_distance`] — adjacent sockets are
//! wired, everything further is multi-hop, exactly the assumption the
//! SLIT fallback already makes. (For 3 nodes the ring *is* the full
//! mesh, so the two fallbacks agree everywhere.)
//!
//! [`ring_distance`]: crate::topology::NumaTopology::ring_distance

/// One undirected interconnect link between two NUMA nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    /// Capacity of the link, GB/s (shared by both directions — QPI
    /// lanes are full-duplex but our demand model aggregates).
    pub bandwidth_gbs: f64,
}

impl Link {
    /// The endpoint that is not `node` (panics if `node` is neither).
    pub fn other(&self, node: usize) -> usize {
        if node == self.a {
            self.b
        } else {
            assert_eq!(node, self.b, "node {node} not on link {self:?}");
            self.a
        }
    }

    /// Unordered endpoint pair (for duplicate detection).
    fn key(&self) -> (usize, usize) {
        (self.a.min(self.b), self.a.max(self.b))
    }
}

/// The machine's interconnect wiring.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkGraph {
    nodes: usize,
    links: Vec<Link>,
}

impl LinkGraph {
    /// Build from an explicit link list (config `[[a, b, gbs]]` rows).
    /// Structure is checked by [`validate`](Self::validate), not here —
    /// config loading surfaces the error instead of panicking.
    pub fn explicit(nodes: usize, links: Vec<Link>) -> Self {
        Self { nodes, links }
    }

    /// The derived fallback: a ring of equal links, matching the shape
    /// `ring_distance` assumes (adjacent = 1 hop). 2 nodes get one
    /// link, 1 node none, 3 nodes a full mesh (ring of 3).
    pub fn ring(nodes: usize, bandwidth_gbs: f64) -> Self {
        let links = match nodes {
            0 | 1 => Vec::new(),
            2 => vec![Link { a: 0, b: 1, bandwidth_gbs }],
            _ => (0..nodes)
                .map(|i| Link { a: i, b: (i + 1) % nodes, bandwidth_gbs })
                .collect(),
        };
        Self { nodes, links }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Structural invariants: endpoints online and distinct, positive
    /// finite capacities, no duplicate wires. Connectivity is checked
    /// by route-table construction (`FabricTopology::new`), which
    /// visits every pair anyway.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen = std::collections::BTreeSet::new();
        for (i, l) in self.links.iter().enumerate() {
            if l.a >= self.nodes || l.b >= self.nodes {
                return Err(format!(
                    "link {i} connects {}-{} on a {}-node machine",
                    l.a, l.b, self.nodes
                ));
            }
            if l.a == l.b {
                return Err(format!("link {i} is a self-loop on node {}", l.a));
            }
            if !l.bandwidth_gbs.is_finite() || l.bandwidth_gbs <= 0.0 {
                return Err(format!(
                    "link {i} ({}-{}) has bandwidth {}",
                    l.a, l.b, l.bandwidth_gbs
                ));
            }
            if !seen.insert(l.key()) {
                return Err(format!("duplicate link {}-{}", l.key().0, l.key().1));
            }
        }
        Ok(())
    }
}

/// Shared matrix validation: square `m` must be symmetric with finite
/// entries. Used by `NumaTopology::validate` on explicit SLIT matrices
/// (an asymmetric or non-finite SLIT breaks both the Reporter's scoring
/// and the fabric's SLIT-weighted routing tie-break) and by fabric
/// route construction.
pub fn check_symmetric(m: &[Vec<f64>]) -> Result<(), String> {
    for (i, row) in m.iter().enumerate() {
        for (j, &x) in row.iter().enumerate() {
            if !x.is_finite() {
                return Err(format!("distance [{i}][{j}] is {x}"));
            }
            if j < i {
                let mirrored = m[j][i];
                if (x - mirrored).abs() > 1e-9 {
                    return Err(format!(
                        "distance matrix asymmetric: [{i}][{j}]={x} vs [{j}][{i}]={mirrored}"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_shapes() {
        assert!(LinkGraph::ring(1, 10.0).is_empty());
        assert_eq!(LinkGraph::ring(2, 10.0).len(), 1);
        let r3 = LinkGraph::ring(3, 10.0);
        assert_eq!(r3.len(), 3, "ring of 3 is the full mesh");
        let r8 = LinkGraph::ring(8, 10.0);
        assert_eq!(r8.len(), 8);
        for g in [r3, r8] {
            g.validate().unwrap();
        }
    }

    #[test]
    fn validate_catches_structural_errors() {
        let bad = |links: Vec<Link>| LinkGraph::explicit(4, links).validate();
        assert!(bad(vec![Link { a: 0, b: 4, bandwidth_gbs: 1.0 }]).is_err());
        assert!(bad(vec![Link { a: 2, b: 2, bandwidth_gbs: 1.0 }]).is_err());
        assert!(bad(vec![Link { a: 0, b: 1, bandwidth_gbs: 0.0 }]).is_err());
        assert!(bad(vec![Link { a: 0, b: 1, bandwidth_gbs: f64::NAN }]).is_err());
        let dup = vec![
            Link { a: 0, b: 1, bandwidth_gbs: 1.0 },
            Link { a: 1, b: 0, bandwidth_gbs: 2.0 },
        ];
        assert!(bad(dup).is_err(), "reversed duplicate detected");
    }

    #[test]
    fn link_other_endpoint() {
        let l = Link { a: 2, b: 5, bandwidth_gbs: 1.0 };
        assert_eq!(l.other(2), 5);
        assert_eq!(l.other(5), 2);
    }

    #[test]
    fn symmetric_check() {
        let ok = vec![vec![10.0, 21.0], vec![21.0, 10.0]];
        assert!(check_symmetric(&ok).is_ok());
        let asym = vec![vec![10.0, 21.0], vec![25.0, 10.0]];
        assert!(check_symmetric(&asym).is_err());
        let nan = vec![vec![10.0, f64::NAN], vec![f64::NAN, 10.0]];
        assert!(check_symmetric(&nan).is_err());
    }
}
