//! The Fig-8 scenario as a standalone example: a consolidated server
//! running Apache-like workers, a MySQL-like database, background
//! daemons, and batch memory hogs. Compares service throughput under
//! the OS default vs the proposed user-level scheduler.
//!
//! Run: `cargo run --release --offline --example server_consolidation`

use numasched::config::{MachineConfig, PolicyKind, SchedulerConfig};
use numasched::experiments::report::{pct, Table};
use numasched::experiments::runner::{run, RunParams};
use numasched::workloads::mix;

fn main() {
    let seed = 11;
    let params = |policy| RunParams {
        machine: MachineConfig::default(),
        scheduler: SchedulerConfig { policy, ..Default::default() },
        specs: mix::fig8_mix(6, 8),
        seed,
        horizon_ms: 40_000.0,
        window_ms: 1_000.0,
        ..Default::default()
    };
    println!("consolidated server: 6 apache workers, 1 mysqld, 8 daemons, 2 batch hogs");
    let base = run(&params(PolicyKind::Default));
    let prop = run(&params(PolicyKind::Proposed));

    let mut t = Table::new(
        "steady-state throughput (work units / 1s window)",
        &["service", "default", "proposed", "improvement"],
    );
    for svc in ["apache", "mysqld", "daemon"] {
        let b = base.throughput_of(svc);
        let p = prop.throughput_of(svc);
        t.row(vec![
            svc.into(),
            format!("{b:.1}"),
            format!("{p:.1}"),
            pct(if b > 0.0 { p / b - 1.0 } else { 0.0 }),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nproposed: {} decisions, {} pages migrated (paper: apache +12.6%, mysql +7%, no manual tuning)",
        prop.scheduler_decisions, prop.total_pages_migrated
    );
}
