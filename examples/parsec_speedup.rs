//! END-TO-END DRIVER (DESIGN.md §5): the paper's headline experiment on
//! the full three-layer stack.
//!
//! Boots the simulated 40-core / 4-node R910, launches the Fig-7 PARSEC
//! mix (12 measured apps + half-CPU/half-memory background), and runs
//! the complete pipeline with the **AOT PJRT artifacts on the scoring
//! hot path** (L1 Pallas kernel -> L2 JAX graph -> HLO text -> PJRT CPU
//! client -> L3 scheduler). Python is not involved at any point of this
//! binary's execution.
//!
//! Prerequisite: `make artifacts`.
//! Run: `cargo run --release --offline --example parsec_speedup`

use numasched::config::PolicyKind;
use numasched::experiments::report::{f2, pct, Table};
use numasched::experiments::{fig7, runner};
use numasched::workloads::parsec;

fn main() {
    let use_pjrt = std::env::args().all(|a| a != "--no-pjrt");
    let seed = 42;
    println!(
        "end-to-end: Fig-7 mix on r910-40core, scoring backend = {}",
        if use_pjrt { "AOT PJRT artifacts" } else { "pure rust" }
    );

    let base = runner::run(&fig7::params(PolicyKind::Default, seed, false));
    let prop = runner::run(&fig7::params(PolicyKind::Proposed, seed, use_pjrt));

    let mut t = Table::new(
        "per-app completion time and speedup (proposed vs default)",
        &["app", "default ms", "proposed ms", "speedup"],
    );
    let mut best = f64::NEG_INFINITY;
    for name in parsec::NAMES {
        let (Some(b), Some(p)) = (base.runtime_of(name), prop.runtime_of(name)) else {
            continue;
        };
        best = best.max(b / p - 1.0);
        t.row(vec![name.into(), format!("{b:.0}"), format!("{p:.0}"), f2(b / p)]);
    }
    print!("{}", t.render());
    println!(
        "\nheadline: up to {} faster (paper: up to 25%)",
        pct(best.max(0.0))
    );
    println!(
        "scheduler: {} decisions, {} process migrations, {} pages migrated",
        prop.scheduler_decisions, prop.total_migrations, prop.total_pages_migrated
    );
    if prop.epoch_ns.count() > 0 {
        println!(
            "scoring epoch (monitor+reporter+{}): mean {:.1} us, max {:.1} us over {} epochs",
            if use_pjrt { "pjrt" } else { "rust" },
            prop.epoch_ns.mean() / 1e3,
            prop.epoch_ns.max() / 1e3,
            prop.epoch_ns.count()
        );
    }
}
