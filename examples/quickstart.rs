//! Quickstart: the numasched public API in ~40 lines.
//!
//! Boots a small simulated NUMA machine, launches two workloads (one
//! important, one background hog), runs the full Monitor -> Reporter ->
//! Scheduler pipeline, and prints what happened.
//!
//! Run: `cargo run --release --offline --example quickstart`

use numasched::config::SchedulerConfig;
use numasched::monitor::Monitor;
use numasched::reporter::{Backend, Reporter};
use numasched::scheduler::UserScheduler;
use numasched::sim::{Machine, Placement, TaskBehavior};
use numasched::topology::NumaTopology;

fn main() {
    // A 2-node, 8-core machine.
    let topo = NumaTopology::from_config(
        &numasched::config::MachineConfig::preset("2node-8core").unwrap(),
    );
    let mut machine = Machine::new(topo.clone(), 1);

    // An important memory-bound app, placed NUMA-blind by the "OS"...
    let app = machine.spawn("myapp", TaskBehavior::mem_bound(4_000.0), 3.0, 2,
                            Placement::LeastLoaded);
    // ...and a background memory hog.
    machine.spawn("hog", TaskBehavior::mem_bound(f64::INFINITY), 0.5, 2,
                  Placement::LeastLoaded);

    // The paper's pipeline. The Monitor reads the machine purely through
    // procfs/sysfs text; importance comes from user space.
    let monitor = Monitor::discover(&machine).expect("discover topology");
    let mut reporter = Reporter::new(
        Backend::Cpu, // or Backend::Pjrt(ScoringEngine::load(...)) after `make artifacts`
        monitor.topo.distance.clone(),
        topo.bandwidth_gbs.clone(),
    );
    reporter.importance.insert("myapp".into(), 3.0);
    // The topology sizes the capacity guard — nothing to patch by hand.
    let mut scheduler = UserScheduler::new(&SchedulerConfig::default(), &topo);

    // Drive everything on virtual time: sample every 10 ms, act on the
    // Reporter's signal.
    while machine.now_ms < 20_000.0 && machine.process(app).unwrap().is_running() {
        machine.step();
        if (machine.now_ms as u64) % 10 == 0 {
            let snapshot = monitor.sample(&machine, machine.now_ms);
            if let Some(report) = reporter.ingest(&snapshot) {
                for d in scheduler.apply(&report, &mut machine) {
                    println!(
                        "t={:>6.0}ms  {:?}: {} node {} -> {} ({} sticky pages)",
                        d.t_ms, d.reason, d.comm, d.from, d.to, d.sticky_pages
                    );
                }
            }
        }
    }

    let p = machine.process(app).unwrap();
    println!(
        "\nmyapp finished in {:.0} ms at mean speed {:.2} after {} migration(s)",
        p.runtime_ms().unwrap_or(f64::NAN),
        p.mean_speed(),
        p.migrations
    );
    println!("scheduler took {} decisions total", scheduler.decisions.len());
}
