//! Run the paper's Monitor (Algorithm 1) against THIS host's real
//! /proc and /sys — the same parsing code the simulator experiments
//! use, on live kernel text.
//!
//! On a non-NUMA host the topology degrades to one node; on a real NUMA
//! box you get per-node page placement of every process.
//!
//! Run: `cargo run --release --offline --example host_monitor`

use std::time::Duration;

use numasched::monitor::{thread::MonitorThread, Monitor};
use numasched::procfs::host::HostProcfs;

fn main() {
    let source = HostProcfs::new();
    let monitor = Monitor::discover(&source).expect("discover host topology");
    println!(
        "host: {} NUMA node(s), >= {} cores/node, SLIT row 0: {:?}",
        monitor.topo.nodes, monitor.topo.cores_per_node, monitor.topo.distance[0]
    );

    let thread = MonitorThread::spawn(monitor, HostProcfs::new(), Duration::from_millis(300));
    for i in 0..4 {
        let snap = thread
            .snapshots
            .recv_timeout(Duration::from_secs(5))
            .expect("snapshot");
        let total_rss: u64 = snap.tasks.iter().map(|t| t.rss_pages).sum();
        let mut top: Vec<_> = snap.tasks.iter().collect();
        top.sort_by_key(|t| std::cmp::Reverse(t.rss_pages));
        println!(
            "sample {i}: {} tasks, {} resident pages; top: {}",
            snap.tasks.len(),
            total_rss,
            top.iter()
                .take(3)
                .map(|t| format!("{}({} pages)", t.comm, t.rss_pages))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    thread.stop();
    println!("monitor stopped cleanly");
}
