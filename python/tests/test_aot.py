"""AOT path tests: HLO text is produced, well-formed, and id-safe."""

import os
import subprocess
import sys

import jax

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def entry_input_count(text):
    """Count entry-computation inputs from the layout header line."""
    header = text.splitlines()[0]
    inputs = header.split("entry_computation_layout={(")[1].split(")->")[0]
    return inputs.count("f32[")


def test_lower_score_placement_to_hlo_text():
    text = aot.lower_entry(model.score_placement, model.aot_input_specs())
    assert "HloModule" in text
    # 8 entry parameters, tuple root with 4 elements.
    assert entry_input_count(text) == 8
    assert "ROOT" in text


def test_lower_node_stats_to_hlo_text():
    text = aot.lower_entry(model.node_stats, model.node_stats_input_specs())
    assert "HloModule" in text
    assert entry_input_count(text) == 3


def test_pallas_lowering_has_no_custom_calls():
    """interpret=True must lower to plain HLO the CPU PJRT client can run."""
    text = aot.lower_entry(model.score_placement, model.aot_input_specs())
    assert "custom-call" not in text.lower()


def test_aot_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        check=True, env=env,
    )
    for name in ["placement_score.hlo.txt", "node_stats.hlo.txt",
                 "manifest.txt"]:
        assert (out / name).exists(), name
    manifest = (out / "manifest.txt").read_text()
    assert "tmax = 64" in manifest
    assert "entry = placement_score inputs=8 outputs=4" in manifest
    assert "entry = node_stats inputs=3 outputs=3" in manifest
