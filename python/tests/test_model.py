"""L2 model tests: padding contract, node stats, AOT specs."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import params, ref

jax.config.update("jax_platform_name", "cpu")


def small_problem(rng, t, n):
    a = rng.uniform(0, 100, (t, n)).astype(np.float32)
    d = np.full((n, n), 21.0, np.float32)
    np.fill_diagonal(d, 10.0)
    mi = rng.uniform(0, 2, (t, 1)).astype(np.float32)
    w = np.ones((t, 1), np.float32)
    u = rng.uniform(0, 4, (1, n)).astype(np.float32)
    b = np.full((1, n), 10.0, np.float32)
    cur = np.zeros((t, n), np.float32)
    cur[np.arange(t), rng.integers(0, n, t)] = 1.0
    mask = np.ones((t, 1), np.float32)
    return tuple(jnp.asarray(x) for x in (a, d, mi, w, u, b, cur, mask))


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 64), n=st.integers(1, 8), seed=st.integers(0, 10**6))
def test_padding_preserves_live_scores(t, n, seed):
    """Scores of live tasks are identical before and after padding."""
    rng = np.random.default_rng(seed)
    args = small_problem(rng, t, n)
    s_small, d_small, r_small, c_small = ref.placement_score(*args)
    padded = model.pad_inputs(*args)
    s_pad, d_pad, r_pad, c_pad = model.score_placement(*padded)
    np.testing.assert_allclose(s_pad[:t, :n], s_small, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(d_pad[:t], d_small, atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 63), n=st.integers(1, 7), seed=st.integers(0, 10**6))
def test_padding_never_attracts_tasks(t, n, seed):
    """No live task may score a padding node above its best real node."""
    rng = np.random.default_rng(seed)
    args = small_problem(rng, t, n)
    padded = model.pad_inputs(*args)
    s_pad, *_ = model.score_placement(*padded)
    s_pad = np.asarray(s_pad)
    best_real = s_pad[:t, :n].max(axis=1)
    best_fake = s_pad[:t, n:].max(axis=1)
    assert np.all(best_fake <= best_real + 1e-5)


def test_node_stats_matches_manual():
    rng = np.random.default_rng(3)
    t, n = 8, 4
    a = rng.uniform(0, 50, (t, n)).astype(np.float32)
    mi = rng.uniform(0, 2, (t, 1)).astype(np.float32)
    b = np.full((1, n), 10.0, np.float32)
    demand, rho, imb = model.node_stats(jnp.asarray(a), jnp.asarray(mi),
                                        jnp.asarray(b))
    ahat = a / np.maximum(a.sum(1, keepdims=True), 1.0)
    want = (ahat * mi).sum(0, keepdims=True)
    np.testing.assert_allclose(demand, want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(rho, want / 10.0, atol=1e-5, rtol=1e-5)
    want_imb = (want.max() - want.min()) / max(want.mean(), 1e-6)
    np.testing.assert_allclose(np.asarray(imb)[0, 0], want_imb, rtol=1e-5)


def test_node_stats_balanced_is_zero_imbalance():
    a = np.ones((4, 4), np.float32) * 25.0
    mi = np.ones((4, 1), np.float32)
    b = np.ones((1, 4), np.float32)
    _, _, imb = model.node_stats(*[jnp.asarray(x) for x in (a, mi, b)])
    np.testing.assert_allclose(np.asarray(imb)[0, 0], 0.0, atol=1e-6)


def test_aot_specs_shapes():
    specs = model.aot_input_specs()
    assert [tuple(s.shape) for s in specs] == [
        (params.TMAX, params.NMAX), (params.NMAX, params.NMAX),
        (params.TMAX, 1), (params.TMAX, 1), (1, params.NMAX),
        (1, params.NMAX), (params.TMAX, params.NMAX), (params.TMAX, 1),
    ]
    stats = model.node_stats_input_specs()
    assert [tuple(s.shape) for s in stats] == [
        (params.TMAX, params.NMAX), (params.TMAX, 1), (1, params.NMAX),
    ]
