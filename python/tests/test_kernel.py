"""Kernel-vs-oracle correctness: the CORE L1 signal.

The Pallas kernel must match the pure-jnp oracle bit-for-bit-ish (1e-5)
over a hypothesis sweep of shapes, heats, intensities, and topologies.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import params, placement, ref

jax.config.update("jax_platform_name", "cpu")


def mk_problem(rng, t, n):
    """Random but well-formed scoring problem instance."""
    a = rng.uniform(0.0, 500.0, (t, n)).astype(np.float32)
    d = np.full((n, n), 21.0, np.float32)
    np.fill_diagonal(d, params.D_LOCAL)
    mi = rng.uniform(0.0, 4.0, (t, 1)).astype(np.float32)
    w = rng.uniform(0.1, 10.0, (t, 1)).astype(np.float32)
    u = rng.uniform(0.0, 8.0, (1, n)).astype(np.float32)
    b = rng.uniform(4.0, 16.0, (1, n)).astype(np.float32)
    cur_idx = rng.integers(0, n, t)
    cur = np.zeros((t, n), np.float32)
    cur[np.arange(t), cur_idx] = 1.0
    mask = (rng.uniform(0, 1, (t, 1)) > 0.2).astype(np.float32)
    return a, d, mi, w, u, b, cur, mask


def assert_matches_ref(args, atol=1e-4):
    got = placement.placement_score(*[jnp.asarray(x) for x in args])
    want = ref.placement_score(*[jnp.asarray(x) for x in args])
    for g, w_, name in zip(got, want, ["s", "d_cur", "r", "c"]):
        np.testing.assert_allclose(g, w_, atol=atol, rtol=1e-4,
                                   err_msg=f"output {name}")


def test_kernel_matches_ref_aot_shape():
    rng = np.random.default_rng(0)
    assert_matches_ref(mk_problem(rng, params.TMAX, params.NMAX))


@pytest.mark.parametrize("t,n", [(16, 2), (32, 4), (64, 8), (128, 8), (16, 1)])
def test_kernel_matches_ref_shapes(t, n):
    rng = np.random.default_rng(t * 131 + n)
    assert_matches_ref(mk_problem(rng, t, n))


@pytest.mark.parametrize("block_t", [8, 16, 32, 64])
def test_kernel_block_size_invariance(block_t):
    """Tiling must not change the numbers."""
    rng = np.random.default_rng(7)
    args = [jnp.asarray(x) for x in mk_problem(rng, 64, 8)]
    got = placement.placement_score(*args, block_t=block_t)
    want = ref.placement_score(*args)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(g, w_, atol=1e-4, rtol=1e-4)


def test_kernel_rejects_ragged_tiles():
    rng = np.random.default_rng(1)
    args = [jnp.asarray(x) for x in mk_problem(rng, 24, 4)]
    with pytest.raises(ValueError, match="not a multiple"):
        placement.placement_score(*args, block_t=16)


@settings(max_examples=40, deadline=None)
@given(
    t_blocks=st.integers(1, 6),
    n=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    heat_scale=st.floats(0.0, 1e4),
    mi_scale=st.floats(0.0, 16.0),
)
def test_kernel_matches_ref_hypothesis(t_blocks, n, seed, heat_scale, mi_scale):
    """Property sweep: shape x magnitude space, kernel == oracle."""
    rng = np.random.default_rng(seed)
    t = t_blocks * params.BLOCK_T
    a, d, mi, w, u, b, cur, mask = mk_problem(rng, t, n)
    a = (a / 500.0 * heat_scale).astype(np.float32)
    mi = (mi / 4.0 * mi_scale).astype(np.float32)
    # Near the rho clip, q = rho/(1-rho) is steep: f32 op-ordering
    # differences between the tiled kernel and the oracle amplify to
    # ~1e-3 relative — same tolerance the rust/HLO equivalence test uses.
    assert_matches_ref((a, d, mi, w, u, b, cur, mask), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_staying_put_scores_zero(seed):
    """Invariant: S[t, cur(t)] == 0 — no predicted gain for not moving."""
    rng = np.random.default_rng(seed)
    a, d, mi, w, u, b, cur, mask = mk_problem(rng, 32, 4)
    s, _, _, _ = placement.placement_score(
        *[jnp.asarray(x) for x in (a, d, mi, w, u, b, cur, mask)])
    at_cur = np.sum(np.asarray(s) * cur, axis=1)
    np.testing.assert_allclose(at_cur, 0.0, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_masked_rows_are_zero(seed):
    """Invariant: padding rows contribute exactly nothing."""
    rng = np.random.default_rng(seed)
    args = mk_problem(rng, 32, 4)
    mask = args[-1]
    outs = placement.placement_score(*[jnp.asarray(x) for x in args])
    dead = (mask[:, 0] == 0.0)
    for o in outs:
        assert np.all(np.asarray(o)[dead] == 0.0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bump=st.floats(0.5, 8.0))
def test_contention_monotone_in_demand(seed, bump):
    """Raising a node's background demand must not raise its score."""
    rng = np.random.default_rng(seed)
    a, d, mi, w, u, b, cur, mask = mk_problem(rng, 32, 4)
    s0, *_ = ref.placement_score(*[jnp.asarray(x)
                                   for x in (a, d, mi, w, u, b, cur, mask)])
    u2 = u.copy()
    u2[0, 1] += bump
    s1, *_ = ref.placement_score(*[jnp.asarray(x)
                                   for x in (a, d, mi, w, u2, b, cur, mask)])
    moved_to_1 = np.asarray(s1)[:, 1] - np.asarray(s0)[:, 1]
    # Tasks currently on node 1 see their d_cur rise, which lifts *other*
    # columns; but the column-1 score itself may only fall for tasks not on 1.
    not_on_1 = cur[:, 1] == 0.0
    assert np.all(moved_to_1[not_on_1] <= 1e-6)


def test_no_nans_on_degenerate_inputs():
    """Zero heat, zero intensity, saturated nodes: finite outputs."""
    t, n = 16, 4
    a = np.zeros((t, n), np.float32)
    d = np.full((n, n), 21.0, np.float32)
    np.fill_diagonal(d, 10.0)
    mi = np.zeros((t, 1), np.float32)
    w = np.ones((t, 1), np.float32)
    u = np.full((1, n), 1e6, np.float32)   # saturated -> rho clipped
    b = np.ones((1, n), np.float32)
    cur = np.zeros((t, n), np.float32)
    cur[:, 0] = 1.0
    mask = np.ones((t, 1), np.float32)
    outs = placement.placement_score(
        *[jnp.asarray(x) for x in (a, d, mi, w, u, b, cur, mask)])
    for o in outs:
        assert np.all(np.isfinite(np.asarray(o)))


def test_vmem_estimate_under_budget():
    """The §Hardware-Adaptation claim: tile working set << 16 MiB VMEM."""
    assert placement.vmem_bytes() < 16 * 1024 * 1024 / 64
