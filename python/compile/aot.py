"""AOT compile path: lower the L2 graphs to HLO text artifacts.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and README gotchas.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Usage: python -m compile.aot [--out-dir ../artifacts] [--tmax 64] [--nmax 8]
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import params, placement


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_manifest(out_dir, tmax, nmax, entries):
    """Record the artifact contract the Rust runtime asserts against."""
    lines = [
        "# numasched AOT manifest — parsed by rust/src/runtime/manifest.rs",
        f"tmax = {tmax}",
        f"nmax = {nmax}",
        f"block_t = {params.BLOCK_T}",
        f"alpha = {params.ALPHA}",
        f"beta = {params.BETA}",
        f"gamma = {params.GAMMA}",
        f"d_local = {params.D_LOCAL}",
        f"rho_max = {params.RHO_MAX}",
        f"vmem_bytes_per_step = {placement.vmem_bytes(params.BLOCK_T, nmax)}",
    ]
    for name, n_in, n_out in entries:
        lines.append(f"entry = {name} inputs={n_in} outputs={n_out}")
    path = os.path.join(out_dir, "manifest.txt")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--tmax", type=int, default=params.TMAX)
    ap.add_argument("--nmax", type=int, default=params.NMAX)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    score_specs = model.aot_input_specs(args.tmax, args.nmax)
    stats_specs = model.node_stats_input_specs(args.tmax, args.nmax)

    artifacts = [
        ("placement_score", model.score_placement, score_specs, 4),
        ("node_stats", model.node_stats, stats_specs, 3),
    ]
    entries = []
    for name, fn, specs, n_out in artifacts:
        text = lower_entry(fn, specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars -> {path}")
        entries.append((name, len(specs), n_out))

    manifest = write_manifest(args.out_dir, args.tmax, args.nmax, entries)
    print(f"wrote manifest -> {manifest}")


if __name__ == "__main__":
    main()
