"""Layer-1 Pallas kernel: fused placement scoring.

One kernel computes everything the Reporter needs per scheduling epoch:
the ``rownorm(A) @ D`` mean-distance matmul (MXU work), the queueing
contention penalty, the per-task degradation factor, and the final
importance-weighted placement score — fused so each ``(BLOCK_T, N)`` task
tile is read from HBM into VMEM exactly once and all elementwise math runs
on the VMEM-resident tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over task
tiles; ``D`` (N x N, N <= 8 padded into a single lane tile) stays resident
across the whole grid; VMEM per step is
``BLOCK_T*(4N + 3)*4 + N*N*4`` bytes ~= 2 KiB at the AOT shape — far under
the 16 MiB VMEM budget, so a simple double-buffered pipeline saturates.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated through the interpret path and
real-TPU performance is *estimated* from the VMEM/MXU structure (DESIGN.md
§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import params


def _score_kernel(a_ref, d_ref, mi_ref, w_ref, u_ref, b_ref, cur_ref,
                  mask_ref, s_ref, dcur_ref, r_ref, c_ref):
    """Fused per-tile scoring body. Shapes per grid step:

    a (BT, N) | d (N, N) | mi/w/mask (BT, 1) | u/b (1, N) | cur (BT, N)
    outputs: s/r/c (BT, N), dcur (BT, 1)
    """
    a = a_ref[...]
    d = d_ref[...]
    mi = mi_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    b = b_ref[...]
    cur = cur_ref[...]
    mask = mask_ref[...]

    # Row-normalized page heat; rowsum reused by the migration-cost term.
    rowsum = jnp.sum(a, axis=1, keepdims=True)
    ahat = a / jnp.maximum(rowsum, 1.0)

    # Mean SLIT access distance per candidate node — the MXU matmul.
    r = jnp.dot(ahat, d, preferred_element_type=jnp.float32)

    # M/M/1 queueing contention penalty per candidate node. The task's
    # own measured traffic (mi spread over its pages) is subtracted from
    # the node totals first — see ref.contention_penalty.
    u_bg = jnp.maximum(u - mi * ahat, 0.0)
    rho = jnp.clip((u_bg + mi) / b, 0.0, params.RHO_MAX)
    c = mi * rho / (1.0 - rho)

    # Predicted degradation on each node; evaluated at the current node it
    # is the paper's contention degradation factor.
    loc = params.ALPHA * (r - params.D_LOCAL) / params.D_LOCAL + params.BETA * c
    d_cur = jnp.sum(loc * cur, axis=1, keepdims=True)

    # Sticky-page migration cost (zero for staying put: cur @ d == 10).
    hop = jnp.dot(cur, d, preferred_element_type=jnp.float32) / params.D_LOCAL - 1.0
    mig = params.GAMMA * jnp.log1p(rowsum) * hop

    s_ref[...] = (w * (d_cur - loc) - mig) * mask
    dcur_ref[...] = d_cur * mask
    r_ref[...] = r * mask
    c_ref[...] = c * mask


@functools.partial(jax.jit, static_argnames=("block_t",))
def placement_score(a, d, mi, w, u, b, cur, mask, *, block_t=params.BLOCK_T):
    """Pallas-tiled placement scoring; same contract as ``ref.placement_score``.

    ``T`` must be a multiple of ``block_t`` (the AOT wrapper in ``model.py``
    pads); ``N`` is carried whole in the lane dimension.
    """
    t, n = a.shape
    if t % block_t != 0:
        raise ValueError(f"T={t} not a multiple of block_t={block_t}")
    grid = (t // block_t,)

    tile_tn = pl.BlockSpec((block_t, n), lambda i: (i, 0))
    tile_t1 = pl.BlockSpec((block_t, 1), lambda i: (i, 0))
    full_nn = pl.BlockSpec((n, n), lambda i: (0, 0))
    full_1n = pl.BlockSpec((1, n), lambda i: (0, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((t, n), jnp.float32),   # s
        jax.ShapeDtypeStruct((t, 1), jnp.float32),   # d_cur
        jax.ShapeDtypeStruct((t, n), jnp.float32),   # r
        jax.ShapeDtypeStruct((t, n), jnp.float32),   # c
    )
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[tile_tn, full_nn, tile_t1, tile_t1, full_1n, full_1n,
                  tile_tn, tile_t1],
        out_specs=[tile_tn, tile_t1, tile_tn, tile_tn],
        out_shape=out_shapes,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, d, mi, w, u, b, cur, mask)


def vmem_bytes(block_t=params.BLOCK_T, n=params.NMAX):
    """Estimated VMEM working set per grid step, in bytes (f32).

    Inputs: a, cur (BT,N); mi, w, mask (BT,1); d (N,N); u, b (1,N).
    Outputs: s, r, c (BT,N); dcur (BT,1).  Intermediates (ahat, rho, loc,
    mig) at most 4 more (BT,N) tiles.
    """
    tiles_tn = 2 + 3 + 4           # inputs + outputs + intermediates
    tiles_t1 = 3 + 1 + 2           # mi/w/mask + dcur + rowsum/d_cur
    return 4 * (tiles_tn * block_t * n + tiles_t1 * block_t + n * n + 2 * n)
