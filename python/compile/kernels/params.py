"""Shared constants of the placement-scoring model.

These are the *contract* between the three layers: the Pallas kernel (L1),
the JAX graph lowered to HLO (L2), and the pure-Rust fallback scorer in
``rust/src/reporter/factors.rs`` (L3).  Any change here must be mirrored in
``rust/src/reporter/factors.rs::consts`` — the cross-layer integration test
(``rust/tests/hlo_equivalence.rs``) pins the two together numerically.

Model recap (see DESIGN.md §3):

* ``R = rownorm(A) @ D`` — mean SLIT access distance of a task if it were
  scheduled on node ``n`` (SLIT local distance is 10, remote >= 11).
* ``rho = clip((U + mi) / B, 0, RHO_MAX)`` — post-move utilization of node
  ``n``'s memory controller, ``C = mi * rho / (1 - rho)`` the M/M/1-style
  queueing (contention) penalty.
* ``loc = ALPHA*(R - D_LOCAL)/D_LOCAL + BETA*C`` — predicted degradation of
  the task when running on node ``n`` (the paper's *contention degradation
  factor* is ``loc`` evaluated at the current node).
* ``S = w * (d_cur - loc) - mig`` — importance-weighted predicted speedup of
  moving to ``n``, less the sticky-page migration cost.
"""

# Degradation model weights.
ALPHA = 1.0     # weight of the remote-access (latency) term
BETA = 1.0      # weight of the queueing-contention term
GAMMA = 0.02    # weight of the sticky-page migration cost term

# SLIT distance to local memory (ACPI convention).
D_LOCAL = 10.0

# Utilization clip: rho/(1-rho) diverges at 1; the paper's scheduler treats
# any controller past this point as saturated.
RHO_MAX = 0.95

# AOT-compiled (padded) problem size: the rust coordinator packs up to TMAX
# live tasks over up to NMAX NUMA nodes and masks the rest.
TMAX = 64
NMAX = 8

# Pallas task-dimension tile.
BLOCK_T = 16
