"""Pure-jnp oracle for the placement-scoring model.

This is the ground truth the Pallas kernel (``placement.py``) and the Rust
fallback scorer are both validated against.  It accepts arbitrary ``(T, N)``
shapes (no padding / tiling constraints), which makes it the natural target
for hypothesis property sweeps.
"""

import jax.numpy as jnp

from . import params


def row_normalize(a):
    """Normalize page-heat rows to access-probability distributions.

    Rows that sum to < 1 page are left (numerically) untouched by dividing
    by ``max(rowsum, 1)`` — a task with no resident pages scores as if it
    had uniform zero heat rather than producing NaNs.
    """
    rowsum = jnp.sum(a, axis=1, keepdims=True)
    return a / jnp.maximum(rowsum, 1.0), rowsum


def contention_penalty(mi, u, b, ahat):
    """M/M/1-style queueing penalty of running a task on each node.

    ``u`` is the *total* controller demand per node as the Monitor
    measures it — which includes the candidate task's own traffic (spread
    over its pages, ``mi * ahat``). That share must be subtracted before
    adding the task's demand at the candidate node, otherwise every task
    sees phantom contention relief on any node it has no pages on and the
    scheduler ping-pongs. ``rho`` is then the post-move utilization and
    the penalty the classic ``rho / (1 - rho)`` waiting-time factor,
    scaled by how memory-bound the task is.
    """
    u_bg = jnp.maximum(u - mi * ahat, 0.0)
    rho = jnp.clip((u_bg + mi) / b, 0.0, params.RHO_MAX)
    return mi * rho / (1.0 - rho)


def local_degradation(r, c):
    """Predicted degradation of a task if it runs on node ``n``.

    The first term is the normalized extra SLIT distance paid per access
    (zero when all pages are local), the second the queueing contention.
    This evaluated at the *current* node is the paper's contention
    degradation factor.
    """
    return params.ALPHA * (r - params.D_LOCAL) / params.D_LOCAL + params.BETA * c


def migration_cost(rowsum, cur, d):
    """Sticky-page migration cost of moving a task's pages to node ``n``.

    Proportional to ``log1p(pages)`` (migration is batched; cost grows
    sub-linearly) and to the SLIT distance between the current node and the
    target, normalized so staying put costs exactly zero.
    """
    hop = (cur @ d) / params.D_LOCAL - 1.0
    return params.GAMMA * jnp.log1p(rowsum) * hop


def placement_score(a, d, mi, w, u, b, cur, mask):
    """Full scoring pass — the Reporter's per-epoch analytics.

    Args:
      a:    (T, N) page heat of task t on node n  (>= 0)
      d:    (N, N) SLIT distance matrix (diag == 10)
      mi:   (T, 1) memory intensity (controller demand) of each task
      w:    (T, 1) user-space importance weight
      u:    (1, N) controller demand per node, excluding the moving task
      b:    (1, N) controller bandwidth capacity per node (> 0)
      cur:  (T, N) one-hot current node of each task
      mask: (T, 1) 1.0 for live tasks, 0.0 for padding

    Returns:
      s:     (T, N) importance-weighted predicted speedup of moving t -> n
      d_out: (T, 1) contention degradation factor at the current placement
      r:     (T, N) mean SLIT access distance if t ran on n
      c:     (T, N) queueing contention penalty if t ran on n
    """
    ahat, rowsum = row_normalize(a)
    r = ahat @ d
    c = contention_penalty(mi, u, b, ahat)
    loc = local_degradation(r, c)
    d_cur = jnp.sum(loc * cur, axis=1, keepdims=True)
    mig = migration_cost(rowsum, cur, d)
    s = (w * (d_cur - loc) - mig) * mask
    return s, d_cur * mask, r * mask, c * mask


def node_stats(a, mi, b):
    """Per-node pressure summary used by the Reporter's trigger logic.

    Returns:
      demand:    (1, N) aggregate controller demand attracted by each node
                 (each task's intensity split by its page distribution)
      rho:       (1, N) utilization = demand / capacity
      imbalance: (1, 1) (max - min) / mean demand — the Reporter fires a
                 reschedule when this exceeds its threshold
    """
    ahat, _ = row_normalize(a)
    demand = jnp.sum(ahat * mi, axis=0, keepdims=True)
    rho = demand / b
    mean = jnp.maximum(jnp.mean(demand), 1e-6)
    imbalance = (jnp.max(demand) - jnp.min(demand)) / mean
    return demand, rho, imbalance.reshape(1, 1)
