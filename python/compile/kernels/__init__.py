"""Layer-1 Pallas kernels for the numasched scoring hot path.

``placement`` holds the fused placement-score kernel (the compute the
Reporter runs every scheduling epoch); ``ref`` is the pure-jnp oracle the
kernels are validated against at build time.
"""
