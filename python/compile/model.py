"""Layer-2 JAX model: the Reporter's per-epoch analytics graph.

Wraps the Layer-1 Pallas kernel with padding / masking so the Rust
coordinator can call one fixed-shape AOT artifact regardless of how many
tasks are currently live, and adds the (small, pure-jnp) node-pressure
summary the Reporter's trigger logic uses.

Build-time only: ``aot.py`` lowers these functions to HLO text once; the
Rust runtime (``rust/src/runtime``) loads and executes the artifacts on the
scheduling hot path.  Python is never on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import params, placement, ref


def pad_inputs(a, d, mi, w, u, b, cur, mask, tmax=params.TMAX, nmax=params.NMAX):
    """Pad arbitrary (T, N) problem tensors to the AOT shape (TMAX, NMAX).

    Padding rows carry mask=0 and score to exactly zero; padding node
    columns get bandwidth 1 and demand RHO_MAX so no real task is ever
    attracted to them (their contention penalty saturates), and distance
    4 * D_LOCAL so their remote term is maximal.
    """
    t, n = a.shape
    if t > tmax or n > nmax:
        raise ValueError(f"problem ({t},{n}) exceeds AOT shape ({tmax},{nmax})")
    a_p = jnp.zeros((tmax, nmax), jnp.float32).at[:t, :n].set(a)
    d_p = jnp.full((nmax, nmax), 4.0 * params.D_LOCAL, jnp.float32)
    d_p = d_p.at[:n, :n].set(d)
    d_p = d_p.at[jnp.arange(nmax), jnp.arange(nmax)].set(params.D_LOCAL)
    mi_p = jnp.zeros((tmax, 1), jnp.float32).at[:t].set(mi)
    w_p = jnp.zeros((tmax, 1), jnp.float32).at[:t].set(w)
    u_p = jnp.full((1, nmax), params.RHO_MAX, jnp.float32).at[:, :n].set(u)
    b_p = jnp.ones((1, nmax), jnp.float32).at[:, :n].set(b)
    # Padding tasks "sit" on node 0 so cur stays one-hot.
    cur_p = jnp.zeros((tmax, nmax), jnp.float32).at[:, 0].set(1.0)
    cur_p = cur_p.at[:t, :n].set(cur)
    cur_p = cur_p.at[:t, 0].set(cur[:, 0] if n > 0 else 1.0)
    mask_p = jnp.zeros((tmax, 1), jnp.float32).at[:t].set(mask)
    return a_p, d_p, mi_p, w_p, u_p, b_p, cur_p, mask_p


def score_placement(a, d, mi, w, u, b, cur, mask):
    """The AOT entry point: fixed (TMAX, NMAX) fused scoring pass.

    All shape/layout decisions live in the Rust packer
    (``rust/src/runtime/pack.rs``); this function assumes already-padded
    inputs and simply invokes the Pallas kernel.
    """
    return placement.placement_score(a, d, mi, w, u, b, cur, mask)


def score_placement_ref(a, d, mi, w, u, b, cur, mask):
    """Oracle twin of ``score_placement`` (pure jnp, any shape)."""
    return ref.placement_score(a, d, mi, w, u, b, cur, mask)


def node_stats(a, mi, b):
    """The AOT entry point for the Reporter's node-pressure summary."""
    return ref.node_stats(a, mi, b)


def aot_input_specs(tmax=params.TMAX, nmax=params.NMAX):
    """ShapeDtypeStructs of ``score_placement``, in argument order."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((tmax, nmax), f32),  # a
        jax.ShapeDtypeStruct((nmax, nmax), f32),  # d
        jax.ShapeDtypeStruct((tmax, 1), f32),     # mi
        jax.ShapeDtypeStruct((tmax, 1), f32),     # w
        jax.ShapeDtypeStruct((1, nmax), f32),     # u
        jax.ShapeDtypeStruct((1, nmax), f32),     # b
        jax.ShapeDtypeStruct((tmax, nmax), f32),  # cur
        jax.ShapeDtypeStruct((tmax, 1), f32),     # mask
    )


def node_stats_input_specs(tmax=params.TMAX, nmax=params.NMAX):
    """ShapeDtypeStructs of ``node_stats``, in argument order."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((tmax, nmax), f32),  # a
        jax.ShapeDtypeStruct((tmax, 1), f32),     # mi
        jax.ShapeDtypeStruct((1, nmax), f32),     # b
    )
